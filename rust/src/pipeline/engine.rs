//! The generic pipeline execution engine: one OS-thread worker per
//! [`StageSpec`], micro-batches streaming over channels in the order a
//! [`Schedule`] dictates.
//!
//! Worker `s` owns the compiled executables of pipeline stage `s`
//! (fwd + rematerialising bwd) and executes the event list its schedule
//! emits: under [`FillDrain`] the forward wave runs `0→…→S-1` with
//! stage `s` starting micro-batch `m` as soon as `(m, s-1)` hands over —
//! the GPipe overlap — then the backward wave drains in reverse; under
//! 1F1B each stage interleaves backwards between forwards after its
//! warm-up. Parameter gradients accumulate locally at the stages that
//! own them, in FIFO micro-batch order under every schedule, so the
//! summed gradients are schedule-invariant bit for bit.
//!
//! The same worker loop also drives the serving subsystem's
//! forward-only path ([`PipelineEngine::run_forward`]): a forward-only
//! spec + the `ServeStream` schedule stream inference batches through
//! the stages with no backward, no stash and no gradient state, and the
//! final stage hands each batch's output to a caller-supplied
//! [`BatchSink`] the moment it completes (the serving subsystem gathers
//! the requested logit rows there and stamps per-batch completion
//! times).
//!
//! Everything crossing a stage boundary is a `HostTensor` copy; on the
//! paper's DGX those copies are the NVLink/PCIe transfers, and the
//! device simulator prices them from the same shapes — and replays the
//! same [`Schedule`] event streams (`simulator::simulate_pipeline_with`).
//!
//! ## Shared state under concurrent `run_epoch` calls
//!
//! `ReplicaGroup` runs several `run_epoch` calls on one engine at once
//! (thread-per-replica; see `pipeline::replica`). The audit of what
//! those calls share, and why none of it needs serialising:
//!
//! * **`spec` / `schedule` / `chunks`** — immutable after construction;
//!   `Schedule::events` is a pure function of `(stage, stages,
//!   m_count)`.
//! * **`execs` (`Arc<Executable>`)** — the compiled stage programs.
//!   The PJRT CPU executable supports concurrent `Execute` calls (see
//!   `runtime::Executable`'s thread-safety note); its call statistics
//!   are lock-free atomics, and its device-resident static-input cache
//!   is a `Mutex`ed map whose buffers are *moved out* per call — two
//!   replicas racing on one key means the loser re-uploads that input
//!   (keys are content identities, so both uploads carry identical
//!   bytes; correctness is unaffected, and the winner's buffer is
//!   reinstated after the call).
//! * **Everything per-call** — channels, stage workers, stashes,
//!   gradient accumulators and the per-stage `params` clones are created
//!   inside `run_epoch`; nothing leaks across calls.
//!
//! Consequently each call's output is a pure function of
//! `(params, microbatches, key)` — concurrent replica execution cannot
//! perturb results, which is what the bit-identical
//! concurrent-vs-sequential invariant in
//! `rust/tests/integration_hybrid.rs` pins.
//!
//! ## Failure semantics
//!
//! A stage failure is data, not an abort: worker panics are caught via
//! `catch_unwind` and surfaced as [`EngineError::StagePanic`], a
//! stalled peer trips the optional link watchdog
//! ([`EngineError::StageTimeout`] instead of a hung `recv`), and the
//! epoch-level triage returns the root-cause error with its typed
//! [`EngineError`] chain intact so callers (the serving fleet's retry
//! loop) can classify it. Injected chaos — see [`crate::faults`] —
//! enters through the same `StageFaults` hook every worker consults
//! before a forward micro-batch.
//!
//! [`FillDrain`]: super::FillDrain

use std::collections::BTreeMap;
use std::panic::AssertUnwindSafe;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::faults::StageFaults;
use crate::runtime::{Engine, ExecInput, Executable, HostTensor};
use crate::util::hash::Fnv1a;

use super::chunkprep::Microbatch;
use super::schedule::{Schedule, StageEvent};
use super::spec::{PipelineSpec, StageInput, StageSpec};

/// Typed stage-failure taxonomy. Every pipeline failure mode that used
/// to be a bare string (or a process-aborting panic) is one of these,
/// kept at the root of the `anyhow` chain `execute()` returns so
/// callers can downcast and classify — the serving fleet retries
/// [`EngineError::is_transient`] errors and treats the rest as replica
/// death.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineError {
    /// A stage worker panicked; caught at the spawn boundary
    /// (`catch_unwind`), never a process abort.
    StagePanic { stage: usize, message: String },
    /// A stage-link `recv` exceeded the watchdog: the upstream stage
    /// stalled or died without closing the channel.
    StageTimeout {
        stage: usize,
        micro_batch: usize,
        what: &'static str,
        waited_s: f64,
    },
    /// A stage link closed mid-run — the peer worker already failed;
    /// its own error is the root cause.
    LinkClosed {
        stage: usize,
        micro_batch: usize,
        what: &'static str,
    },
    /// A fault-injection plan failed this micro-batch on purpose
    /// (`TransientExecError`); retryable by construction.
    InjectedFault { stage: usize, micro_batch: usize },
}

impl EngineError {
    /// Link-teardown collateral: the peer's own error is the root cause.
    pub fn is_disconnect(&self) -> bool {
        matches!(self, EngineError::LinkClosed { .. })
    }

    /// Retry-worthy: re-running the replica may succeed.
    pub fn is_transient(&self) -> bool {
        matches!(self, EngineError::InjectedFault { .. })
    }
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::StagePanic { stage, message } => {
                write!(f, "stage {stage} worker panicked: {message}")
            }
            EngineError::StageTimeout {
                stage,
                micro_batch,
                what,
                waited_s,
            } => write!(
                f,
                "stage {stage}: timed out after {waited_s:.3}s waiting for {what} \
                 micro-batch {micro_batch} (watchdog; upstream stage stalled or died)"
            ),
            EngineError::LinkClosed {
                stage,
                micro_batch,
                what,
            } => write!(
                f,
                "stage {stage}: {what} channel closed at micro-batch {micro_batch} \
                 (peer stage failed)"
            ),
            EngineError::InjectedFault { stage, micro_batch } => write!(
                f,
                "stage {stage}: injected transient execution fault on \
                 micro-batch {micro_batch}"
            ),
        }
    }
}

impl std::error::Error for EngineError {}

/// Render a caught panic payload (the `Box<dyn Any>` from
/// `catch_unwind`/`join`) as best we can.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Per-stage wall-clock accounting for one epoch.
#[derive(Debug, Clone, Default)]
pub struct StageTiming {
    /// Seconds inside the fwd executable, per micro-batch.
    pub fwd_s: Vec<f64>,
    /// Seconds inside the bwd executable(s), per micro-batch.
    pub bwd_s: Vec<f64>,
    /// Total busy seconds (fwd + bwd + local bookkeeping).
    pub busy_s: f64,
}

/// Result of one pipeline epoch (one optimiser step's worth of work).
#[derive(Debug)]
pub struct EpochOutput {
    /// Sum of masked NLL over all micro-batches.
    pub loss_sum: f64,
    /// Total mask count (normalisation for loss and grads).
    pub mask_count: f64,
    /// Gradients w.r.t. the loss SUM, in manifest param order.
    pub grads: Vec<HostTensor>,
    /// Per micro-batch: (original node ids, row-major log-probs).
    pub logp: Vec<(Vec<u32>, Vec<f32>)>,
    pub stage_timings: Vec<StageTiming>,
    /// True wall-clock of the epoch. For a single pipeline this is the
    /// engine run; for an R-replica group it is the span of the replica
    /// phase — measured across the concurrent execution (waves included
    /// when threads < R), or the sum of replica spans when they ran
    /// sequentially (`--replica-threads 1`).
    pub wall_s: f64,
    /// Aggregate per-replica execution seconds (the sum over replicas —
    /// what `wall_s` used to report before concurrent execution). Equal
    /// to `wall_s` for a single pipeline; their ratio is the realised
    /// host-concurrency speedup.
    pub replica_cpu_s: f64,
    /// Host seconds spent in the cross-replica gradient all-reduce.
    /// Zero for a plain single-pipeline epoch; `ReplicaGroup` fills it
    /// when merging R > 1 replica outputs.
    pub allreduce_s: f64,
}

/// Compiled executables of one stage.
struct StageExec {
    fwd: Arc<Executable>,
    bwd: Arc<Executable>,
}

/// A compiled pipeline for one (dataset, backend, chunk-count) triple,
/// built from a declarative [`PipelineSpec`] and driven by a
/// [`Schedule`].
pub struct PipelineEngine {
    spec: PipelineSpec,
    schedule: Arc<dyn Schedule>,
    execs: Vec<StageExec>,
    pub chunks: usize,
    pub backend: String,
    pub artifact_names: Vec<String>,
    /// Keep static micro-batch inputs (features, graph tensors,
    /// labels+mask) resident on the device across stage calls, keyed by
    /// the micro-batch's content-version id. Off by default — the
    /// paper's implementation re-uploads per call; `PrepMode::Cached`
    /// and `::Overlap` turn it on.
    pub device_resident: bool,
    /// Stage-link watchdog: a worker's `recv` waiting longer than this
    /// fails with [`EngineError::StageTimeout`] instead of hanging
    /// forever on a stalled peer. `None` (the default, and the training
    /// path) keeps the blocking recv.
    pub watchdog_s: Option<f64>,
    /// Injected execution faults (see [`crate::faults`]): every stage
    /// worker consults the table before each forward micro-batch.
    /// `None` (the default) is a no-op.
    pub faults: Option<Arc<StageFaults>>,
    /// Content version of the parameter vector (the store's
    /// `Version::content_hash`). With `device_resident` on, setting
    /// this keys each stage's parameter tensors into the
    /// device-resident static-input cache under
    /// `fnv("param", version, flat index)` — so a serving run uploads
    /// a parameter version once and every later batch is a cache hit,
    /// and a hot-swap to a new version re-uploads exactly once under
    /// fresh keys. `None` (the default, and the training path, where
    /// params change every step) uploads params on every call.
    pub param_version: Option<u64>,
}

type Msg = (usize, HostTensor);

/// A stage-link sender. Training runs use unbounded channels (a
/// schedule's event structure already caps in-flight work at the
/// micro-batch count, which is small). Forward-only serving runs use
/// *bounded* forward links instead: a trace can carry thousands of
/// batches, and without backpressure a fast stage 0 would pile one
/// full-graph activation per in-flight batch into the channel to the
/// bottleneck stage. A bounded send blocks the producer — safe here
/// because the link graph is an acyclic chain — so in-flight
/// activations are capped at `SERVE_LINK_DEPTH + 1` per boundary.
enum LinkTx {
    Unbounded(Sender<Msg>),
    Bounded(SyncSender<Msg>),
}

impl LinkTx {
    fn send(&self, v: Msg) -> Result<(), mpsc::SendError<Msg>> {
        match self {
            LinkTx::Unbounded(t) => t.send(v),
            LinkTx::Bounded(t) => t.send(v),
        }
    }
}

/// Queued batches per forward link in a forward-only (serving) run.
const SERVE_LINK_DEPTH: usize = 2;

/// Consumer of final-stage forward outputs in a forward-only run: called
/// with `(batch index, primary output)` from the final stage's worker
/// thread, strictly in batch order (the serve schedule is FIFO). An
/// error tears the run down like any stage failure.
pub type BatchSink<'s> = &'s (dyn Fn(usize, HostTensor) -> Result<()> + Sync);

/// Where a worker's micro-batches come from: one entry per batch (the
/// training path), or one shared entry every batch re-reads (the serve
/// path, where every inference batch runs over the same device-resident
/// full-graph tensors and only the requested output rows differ).
#[derive(Clone, Copy)]
enum MbSource<'a> {
    PerBatch(&'a [Microbatch]),
    Shared(&'a Microbatch, usize),
}

impl<'a> MbSource<'a> {
    fn len(&self) -> usize {
        match self {
            MbSource::PerBatch(s) => s.len(),
            MbSource::Shared(_, n) => *n,
        }
    }

    fn get(&self, m: usize) -> &'a Microbatch {
        match self {
            MbSource::PerBatch(s) => &s[m],
            MbSource::Shared(mb, _) => mb,
        }
    }
}

impl PipelineEngine {
    pub fn new(
        engine: &Engine,
        dataset: &str,
        backend: &str,
        chunks: usize,
        spec: PipelineSpec,
        schedule: Arc<dyn Schedule>,
    ) -> Result<PipelineEngine> {
        spec.validate()?;
        anyhow::ensure!(
            !spec.forward_only,
            "forward-only specs have no backward artifacts; build them \
             with PipelineEngine::new_forward_only"
        );
        let name = |kind: &str| format!("{dataset}_{backend}_c{chunks}_{kind}");
        let mut artifact_names = Vec::with_capacity(2 * spec.stages.len());
        let mut execs = Vec::with_capacity(spec.stages.len());
        for st in &spec.stages {
            let fwd_name = name(&st.fwd_kind);
            let bwd_name = name(&st.bwd_kind);
            execs.push(StageExec {
                fwd: engine.executable(&fwd_name)?,
                bwd: engine.executable(&bwd_name)?,
            });
            artifact_names.push(fwd_name);
            artifact_names.push(bwd_name);
        }
        Ok(PipelineEngine {
            spec,
            schedule,
            execs,
            chunks,
            backend: backend.to_string(),
            artifact_names,
            device_resident: false,
            watchdog_s: None,
            faults: None,
            param_version: None,
        })
    }

    /// Build an inference-only pipeline from a forward-only spec: only
    /// the forward executables are loaded (the spec's `bwd_kind`s are
    /// placeholders — each stage's `bwd` slot aliases its `fwd` and is
    /// never invoked, because the only entry point,
    /// [`PipelineEngine::run_forward`], rejects schedules that emit
    /// backward events).
    pub fn new_forward_only(
        engine: &Engine,
        dataset: &str,
        backend: &str,
        chunks: usize,
        spec: PipelineSpec,
        schedule: Arc<dyn Schedule>,
    ) -> Result<PipelineEngine> {
        spec.validate()?;
        anyhow::ensure!(
            spec.forward_only,
            "PipelineEngine::new_forward_only requires a forward-only spec"
        );
        let name = |kind: &str| format!("{dataset}_{backend}_c{chunks}_{kind}");
        let mut artifact_names = Vec::with_capacity(spec.stages.len());
        let mut execs = Vec::with_capacity(spec.stages.len());
        for st in &spec.stages {
            let fwd_name = name(&st.fwd_kind);
            let fwd = engine.executable(&fwd_name)?;
            execs.push(StageExec { bwd: fwd.clone(), fwd });
            artifact_names.push(fwd_name);
        }
        Ok(PipelineEngine {
            spec,
            schedule,
            execs,
            chunks,
            backend: backend.to_string(),
            artifact_names,
            device_resident: false,
            watchdog_s: None,
            faults: None,
            param_version: None,
        })
    }

    pub fn spec(&self) -> &PipelineSpec {
        &self.spec
    }

    pub fn schedule_name(&self) -> &'static str {
        self.schedule.name()
    }

    /// Each stage executable exactly once: forward-only engines alias
    /// `bwd` to `fwd`, and counting the aliased slot would double every
    /// stat a serving run reports.
    fn unique_execs(&self) -> impl Iterator<Item = &Executable> + '_ {
        self.execs
            .iter()
            .flat_map(|e| {
                let aliased = Arc::ptr_eq(&e.fwd, &e.bwd);
                [
                    Some(e.fwd.as_ref()),
                    if aliased { None } else { Some(e.bwd.as_ref()) },
                ]
            })
            .flatten()
    }

    /// Cumulative host↔device transfer seconds (upload + download)
    /// across every stage executable — snapshot before/after a run for
    /// the `transfer_s` metric (executables are process-cached, so the
    /// raw totals span the engine's lifetime, not one run).
    pub fn transfer_seconds(&self) -> f64 {
        self.unique_execs()
            .map(|e| e.exec_stats().transfer_s())
            .sum()
    }

    /// Static-input cache hits across every stage executable.
    pub fn static_hits(&self) -> u64 {
        self.unique_execs()
            .map(|e| e.exec_stats().static_hits)
            .sum()
    }

    /// Drop all device-resident static input buffers held by this
    /// pipeline's stage executables.
    pub fn clear_static_buffers(&self) {
        for e in self.unique_execs() {
            e.clear_static_buffers();
        }
    }

    /// Run one synchronous pipeline step over the prepared micro-batches.
    ///
    /// `params` is the full flat parameter vector in manifest order;
    /// each stage takes the slice its spec owns. `key` seeds the
    /// per-micro-batch dropout keys: micro-batch m uses
    /// (key.0 + m, key.1), so chunks=1 reproduces the monolithic
    /// train_step bit-for-bit (integration_pipeline.rs asserts this).
    pub fn run_epoch(
        &self,
        params: &[HostTensor],
        microbatches: &[Microbatch],
        key: (u32, u32),
    ) -> Result<EpochOutput> {
        anyhow::ensure!(
            !self.spec.forward_only,
            "run_epoch trains; forward-only pipelines serve through run_forward"
        );
        self.execute(params, MbSource::PerBatch(microbatches), key, None)
    }

    /// Run a forward-only streaming pass: `batches` inference batches
    /// through the stage workers under this engine's (forward-only)
    /// schedule, every batch reading the same shared micro-batch `mb`
    /// (the device-resident full-graph inputs; with `device_resident`
    /// on, uploads happen once and every subsequent batch is a
    /// static-cache hit). The final stage delivers each batch's primary
    /// output to `sink` the moment it completes, in batch order, from
    /// the final worker's thread. The returned [`EpochOutput`] carries
    /// the per-stage timings and wall-clock; its training fields
    /// (loss/grads/logp) are zero/empty.
    pub fn run_forward(
        &self,
        params: &[HostTensor],
        mb: &Microbatch,
        batches: usize,
        sink: BatchSink<'_>,
    ) -> Result<EpochOutput> {
        anyhow::ensure!(
            self.spec.forward_only,
            "run_forward serves; training pipelines step through run_epoch"
        );
        // A backward event under a forward-only spec is rejected inside
        // the worker (the event lists are only materialised once, in
        // execute()). The key is irrelevant: validate() guarantees
        // forward-only specs declare no dropout-key input.
        self.execute(params, MbSource::Shared(mb, batches), (0, 0), Some(sink))
    }

    /// Shared core of [`run_epoch`] and [`run_forward`]: spawn one
    /// worker per stage over the schedule's event lists and merge the
    /// worker outputs.
    ///
    /// [`run_epoch`]: PipelineEngine::run_epoch
    /// [`run_forward`]: PipelineEngine::run_forward
    fn execute(
        &self,
        params: &[HostTensor],
        microbatches: MbSource<'_>,
        key: (u32, u32),
        sink: Option<BatchSink<'_>>,
    ) -> Result<EpochOutput> {
        anyhow::ensure!(
            params.len() == self.spec.param_count,
            "expected {} flat params, got {}",
            self.spec.param_count,
            params.len()
        );
        let m_count = microbatches.len();
        anyhow::ensure!(m_count >= 1, "no micro-batches");
        let n_stages = self.spec.stages.len();
        let watchdog = self.watchdog_s.map(Duration::from_secs_f64);
        // A fresh run must not inherit a previous attempt's abort flag
        // (the fleet retry loop reuses one StageFaults table so
        // transient counters burn down across attempts).
        if let Some(f) = &self.faults {
            f.reset_abort();
        }
        // Workers borrow the micro-batches directly (scoped threads): no
        // per-epoch clone of the full prepared set. Forward-only specs
        // are deterministic (validate() bans the Key input), so a long
        // serve trace doesn't allocate one unread key tensor per batch.
        let keys: Vec<HostTensor> = if self.spec.forward_only {
            Vec::new()
        } else {
            (0..m_count)
                .map(|m| HostTensor::key(key.0.wrapping_add(m as u32), key.1))
                .collect()
        };

        let wall = Instant::now();
        // Stage workers record trace events on the replica (pid) of the
        // thread that called execute(): thread-locals don't cross the
        // scoped spawns below, so the binding is captured here and
        // re-established inside each worker.
        let trace_pid = crate::trace::current_pid();

        // One (fwd, bwd) channel pair per stage boundary: fwd b -> b+1,
        // bwd b+1 -> b. Receivers are not Clone, so build Option slots
        // each worker takes from. Forward links are bounded in
        // forward-only (serving) runs — see [`LinkTx`] — so a long
        // trace cannot pile activations into the channels.
        let bounded = sink.is_some();
        let mut fwd_in: Vec<Option<Receiver<Msg>>> = (0..n_stages).map(|_| None).collect();
        let mut fwd_out: Vec<Option<LinkTx>> = (0..n_stages).map(|_| None).collect();
        let mut bwd_in: Vec<Option<Receiver<Msg>>> = (0..n_stages).map(|_| None).collect();
        let mut bwd_out: Vec<Option<LinkTx>> = (0..n_stages).map(|_| None).collect();
        for b in 0..n_stages - 1 {
            let (ftx, frx) = if bounded {
                let (tx, rx) = mpsc::sync_channel::<Msg>(SERVE_LINK_DEPTH);
                (LinkTx::Bounded(tx), rx)
            } else {
                let (tx, rx) = mpsc::channel::<Msg>();
                (LinkTx::Unbounded(tx), rx)
            };
            fwd_out[b] = Some(ftx);
            fwd_in[b + 1] = Some(frx);
            // Forward-only runs never carry a cotangent; skip the
            // backward links entirely.
            if !bounded {
                let (btx, brx) = mpsc::channel::<Msg>();
                bwd_out[b + 1] = Some(LinkTx::Unbounded(btx));
                bwd_in[b] = Some(brx);
            }
        }

        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n_stages);
            for (s, (st, ex)) in self.spec.stages.iter().zip(&self.execs).enumerate() {
                let worker = StageWorker {
                    stage: s,
                    spec: st,
                    fwd: ex.fwd.clone(),
                    bwd: ex.bwd.clone(),
                    params: params[st.params.0..st.params.1].to_vec(),
                    mbs: microbatches,
                    keys: &keys,
                    device_resident: self.device_resident,
                    param_version: self.param_version,
                    events: self.schedule.events(s, n_stages, m_count),
                    sink,
                    fwd_in: fwd_in[s].take(),
                    fwd_out: fwd_out[s].take(),
                    bwd_in: bwd_in[s].take(),
                    bwd_out: bwd_out[s].take(),
                    watchdog,
                    faults: self.faults.clone(),
                    trace_pid,
                };
                // Catch panics at the spawn boundary: a panicking stage
                // becomes a structured StagePanic error, never a process
                // abort. Any failure trips the shared fault-abort flag so
                // an injected stall sleeping on a sibling worker unwinds
                // at watchdog speed instead of sleeping out its full
                // duration.
                let faults = self.faults.clone();
                handles.push(scope.spawn(move || {
                    let out = std::panic::catch_unwind(AssertUnwindSafe(|| worker.run()))
                        .unwrap_or_else(|payload| {
                            Err(anyhow::Error::new(EngineError::StagePanic {
                                stage: s,
                                message: panic_message(payload.as_ref()),
                            }))
                        });
                    if out.is_err() {
                        if let Some(f) = &faults {
                            f.trip_abort();
                        }
                    }
                    out
                }));
            }

            // Join everything, then report the most informative error: a
            // failing stage tears its channels down, so peers see their
            // sends/receives fail with LinkClosed — the root cause is
            // the one error that is NOT link-teardown collateral. The
            // root is returned with its typed EngineError chain intact
            // (not stringified) so callers can downcast and classify.
            let results: Vec<Result<WorkerOutput>> = handles
                .into_iter()
                .enumerate()
                .map(|(s, h)| {
                    h.join().unwrap_or_else(|payload| {
                        Err(anyhow::Error::new(EngineError::StagePanic {
                            stage: s,
                            message: panic_message(payload.as_ref()),
                        }))
                    })
                })
                .collect();
            let mut outputs: Vec<WorkerOutput> = Vec::with_capacity(n_stages);
            let mut errors: Vec<anyhow::Error> = Vec::new();
            for res in results {
                match res {
                    Ok(out) => outputs.push(out),
                    Err(e) => errors.push(e),
                }
            }
            if !errors.is_empty() {
                let is_teardown = |e: &anyhow::Error| {
                    e.chain().any(|c| {
                        c.downcast_ref::<EngineError>()
                            .is_some_and(EngineError::is_disconnect)
                    }) || format!("{e:#}").contains("channel closed")
                };
                let idx = errors
                    .iter()
                    .position(|e| !is_teardown(e))
                    .unwrap_or(0);
                let peers = errors.len() - 1;
                let root = errors.swap_remove(idx);
                return Err(root.context(if peers > 0 {
                    format!(
                        "pipeline stage failed ({peers} peer link-teardown \
                         error(s) suppressed)"
                    )
                } else {
                    "pipeline stage failed".to_string()
                }));
            }

            let mut loss_sum = 0.0f64;
            let mut mask_count = 0.0f64;
            let mut logp: Vec<(Vec<u32>, Vec<f32>)> = Vec::new();
            let mut stage_timings = Vec::with_capacity(n_stages);
            let mut owned_grads: Vec<(usize, Vec<HostTensor>)> = Vec::new();
            for (st, out) in self.spec.stages.iter().zip(outputs) {
                loss_sum += out.loss_sum;
                mask_count += out.mask_count;
                stage_timings.push(out.timing);
                if !out.logp.is_empty() {
                    logp = out.logp;
                }
                if st.param_count() > 0 {
                    owned_grads.push((st.params.0, out.grads));
                }
            }
            // Stage-local accumulators concatenate back into the flat
            // manifest order (validate() guarantees the slices tile it).
            owned_grads.sort_by_key(|(start, _)| *start);
            let grads: Vec<HostTensor> =
                owned_grads.into_iter().flat_map(|(_, g)| g).collect();

            let wall_s = wall.elapsed().as_secs_f64();
            Ok(EpochOutput {
                loss_sum,
                mask_count,
                grads,
                logp,
                stage_timings,
                wall_s,
                replica_cpu_s: wall_s,
                allreduce_s: 0.0,
            })
        })
    }
}

/// Everything one stage worker produces in an epoch. Loss fields are
/// zero and `logp` empty on every stage but the final (loss) stage;
/// `grads` is empty on stages that own no parameters.
#[derive(Default)]
struct WorkerOutput {
    grads: Vec<HostTensor>,
    timing: StageTiming,
    loss_sum: f64,
    mask_count: f64,
    logp: Vec<(Vec<u32>, Vec<f32>)>,
}

/// The generic stage worker: executes one schedule-ordered event list
/// against the stage's compiled executables. Replaces the four bespoke
/// per-stage closures of the fixed 4-stage engine.
struct StageWorker<'a> {
    stage: usize,
    spec: &'a StageSpec,
    fwd: Arc<Executable>,
    bwd: Arc<Executable>,
    /// This stage's owned parameter slice (cloned per epoch).
    params: Vec<HostTensor>,
    mbs: MbSource<'a>,
    keys: &'a [HostTensor],
    /// Mark per-micro-batch static inputs for device residency.
    device_resident: bool,
    /// Content version of the parameter vector: with `device_resident`,
    /// params upload once per version (see
    /// [`PipelineEngine::param_version`]).
    param_version: Option<u64>,
    events: Vec<StageEvent>,
    /// Forward-only runs: the final stage streams each batch's primary
    /// output here instead of accumulating `logp`.
    sink: Option<BatchSink<'a>>,
    fwd_in: Option<Receiver<Msg>>,
    fwd_out: Option<LinkTx>,
    bwd_in: Option<Receiver<Msg>>,
    bwd_out: Option<LinkTx>,
    /// Stage-link recv timeout (see [`PipelineEngine::watchdog_s`]).
    watchdog: Option<Duration>,
    /// Injected execution faults, consulted before each forward batch.
    faults: Option<Arc<StageFaults>>,
    /// Replica (trace pid) of the thread that called `execute()` — the
    /// worker rebinds its own thread to `(trace_pid, stage)` so spans
    /// land on the right timeline lane.
    trace_pid: u32,
}

impl StageWorker<'_> {
    fn run(mut self) -> Result<WorkerOutput> {
        crate::trace::bind(self.trace_pid, self.stage as u32);
        let m_count = self.mbs.len();
        // The final stage derives the loss; the first has no upstream.
        let is_loss = self.fwd_out.is_none();
        let is_first = self.bwd_out.is_none();
        let mut fwd_inbox = self.fwd_in.take().map(OrderedInbox::new);
        let mut bwd_inbox = self.bwd_in.take().map(OrderedInbox::new);
        // Only allocated where used: the stash only when this stage's
        // backward replays its input, the gradient accumulators only in
        // training runs (a forward-only run returns no gradients, and
        // the Bwd guard below keeps `accumulate` unreachable).
        let mut stash: Vec<Option<HostTensor>> = if self.spec.stashes_activation() {
            vec![None; m_count]
        } else {
            Vec::new()
        };
        let mut acc: Vec<HostTensor> = if self.sink.is_some() {
            Vec::new()
        } else {
            self.params
                .iter()
                .map(|p| HostTensor::zeros_f32(p.shape().to_vec()))
                .collect()
        };
        let mut timing = StageTiming::default();
        let mut loss_sum = 0.0f64;
        let mut mask_count = 0.0f64;
        let mut logp: Vec<(Vec<u32>, Vec<f32>)> = if is_loss && self.sink.is_none() {
            vec![Default::default(); m_count]
        } else {
            Vec::new()
        };
        let busy = Instant::now();

        for &ev in &self.events {
            match ev {
                StageEvent::Fwd(m) => {
                    // Fault-injection hook (no-op without a plan): may
                    // sleep (stall / slow replica) or fail the batch
                    // with a typed transient error.
                    if let Some(f) = &self.faults {
                        f.before_fwd(self.stage, m)?;
                    }
                    let inbound = match &mut fwd_inbox {
                        Some(inbox) => {
                            let _wait =
                                crate::trace::span1("recv_activation", "mb", m as i64);
                            Some(inbox.recv(m, self.stage, "activation", self.watchdog)?)
                        }
                        None => None,
                    };
                    let exec_span = crate::trace::span1("fwd", "mb", m as i64);
                    let t0 = Instant::now();
                    let out = {
                        let inp = self
                            .assemble(&self.spec.fwd_inputs, m, inbound.as_ref())?;
                        self.fwd.run_inputs(&inp)
                    }
                    .with_context(|| {
                        format!("stage {} fwd (micro-batch {m})", self.stage)
                    })?;
                    timing.fwd_s.push(t0.elapsed().as_secs_f64());
                    drop(exec_span);
                    // GPipe rematerialisation: stash only the stage input.
                    if self.spec.stashes_activation() {
                        stash[m] = inbound;
                    }
                    let primary = out
                        .into_iter()
                        .next()
                        .with_context(|| format!("stage {} fwd has no outputs", self.stage))?;
                    if let Some(tx) = &self.fwd_out {
                        let _send =
                            crate::trace::span1("send_activation", "mb", m as i64);
                        send_link(tx, m, primary, self.stage, "activation")?;
                    } else if let Some(sink) = self.sink {
                        // Forward-only run: stream the batch output out
                        // the moment it exists (the serving subsystem
                        // gathers requested rows and stamps completion).
                        let _deliver = crate::trace::span1("deliver", "mb", m as i64);
                        sink(m, primary).with_context(|| {
                            format!("batch sink failed on batch {m}")
                        })?;
                    } else {
                        // Final stage: the forward emits the log-probs
                        // the trainer records for training accuracy.
                        logp[m] = (
                            self.mbs.get(m).nodes.clone(),
                            primary.as_f32()?.to_vec(),
                        );
                    }
                }
                StageEvent::Bwd(m) => {
                    // A sink marks a forward-only run: its (placeholder)
                    // backward executable must never fire.
                    anyhow::ensure!(
                        self.sink.is_none(),
                        "stage {}: schedule emitted Bwd({m}) in a \
                         forward-only run (use a forward-only schedule \
                         such as ServeStream)",
                        self.stage
                    );
                    let cotangent = match &mut bwd_inbox {
                        Some(inbox) => {
                            let _wait =
                                crate::trace::span1("recv_cotangent", "mb", m as i64);
                            Some(inbox.recv(m, self.stage, "cotangent", self.watchdog)?)
                        }
                        None => None,
                    };
                    let stashed = if self.spec.stashes_activation() {
                        Some(stash[m].take().with_context(|| {
                            format!(
                                "stage {}: no stashed activation for micro-batch {m} \
                                 (schedule ran Bwd before Fwd?)",
                                self.stage
                            )
                        })?)
                    } else {
                        None
                    };
                    let mut inp =
                        self.assemble(&self.spec.bwd_inputs, m, stashed.as_ref())?;
                    if let Some(g) = cotangent.as_ref() {
                        inp.push(ExecInput::Dyn(g));
                    }
                    let exec_span = crate::trace::span1("bwd", "mb", m as i64);
                    let t0 = Instant::now();
                    let mut out = self.bwd.run_inputs(&inp).with_context(|| {
                        format!("stage {} bwd (micro-batch {m})", self.stage)
                    })?;
                    timing.bwd_s.push(t0.elapsed().as_secs_f64());
                    drop(exec_span);
                    let upstream = if is_first {
                        None
                    } else {
                        Some(out.pop().with_context(|| {
                            format!("stage {} bwd emitted no upstream cotangent", self.stage)
                        })?)
                    };
                    if is_loss {
                        anyhow::ensure!(
                            out.len() >= 2,
                            "loss-stage bwd must emit (loss_sum, mask_count, ...)"
                        );
                        loss_sum += out[0].scalar_value()? as f64;
                        mask_count += out[1].scalar_value()? as f64;
                        out.drain(..2);
                    }
                    accumulate(&mut acc, &out)?;
                    if let (Some(tx), Some(g)) = (&self.bwd_out, upstream) {
                        let _send =
                            crate::trace::span1("send_cotangent", "mb", m as i64);
                        send_link(tx, m, g, self.stage, "cotangent")?;
                    }
                }
            }
        }
        timing.busy_s = busy.elapsed().as_secs_f64();
        Ok(WorkerOutput { grads: acc, timing, loss_sum, mask_count, logp })
    }

    /// Build an executable input list (borrowed — no host-side tensor
    /// clones): the stage's parameter slice, then each declared
    /// [`StageInput`] in order. Per-micro-batch static inputs (features,
    /// graph tensors, labels+mask) are marked device-resident when the
    /// engine's `device_resident` flag is on, keyed by the micro-batch's
    /// content-version id so a rebuilt batch re-uploads; params,
    /// activations and dropout keys change per epoch/call and stay
    /// dynamic.
    fn assemble<'t>(
        &'t self,
        inputs: &[StageInput],
        m: usize,
        activation: Option<&'t HostTensor>,
    ) -> Result<Vec<ExecInput<'t>>> {
        let mb = self.mbs.get(m);
        let resident = self.device_resident;
        // Slot layout inside one micro-batch's static-key space:
        // 0 = features, 1..=3 = graph tensors, 5 = labels, 6 = mask.
        let mark = |slot: u64, t: &'t HostTensor| -> ExecInput<'t> {
            if resident {
                ExecInput::Static((mb.id << STATIC_SLOT_BITS) | slot, t)
            } else {
                ExecInput::Dyn(t)
            }
        };
        // Versioned serving params ride the same static cache: keyed by
        // (content version, global flat index), so a new version —
        // fresh keys — re-uploads exactly once, and the swapped-out
        // version's buffers age out of use without a flush mid-run.
        let mut inp: Vec<ExecInput<'t>> = match self.param_version {
            Some(version) if resident => self
                .params
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let mut h = Fnv1a::new();
                    h.write(b"param");
                    h.write_u64(version);
                    h.write_usize(self.spec.params.0 + i);
                    ExecInput::Static(h.finish(), p)
                })
                .collect(),
            _ => self.params.iter().map(ExecInput::Dyn).collect(),
        };
        for i in inputs {
            match i {
                StageInput::Activation => inp.push(ExecInput::Dyn(
                    activation.with_context(|| {
                        format!("stage {}: no activation for micro-batch {m}", self.stage)
                    })?,
                )),
                StageInput::Features => inp.push(mark(0, &mb.x)),
                StageInput::Graph => {
                    for (j, g) in mb.graph.iter().enumerate() {
                        inp.push(mark(1 + j as u64, g));
                    }
                }
                StageInput::Key => inp.push(ExecInput::Dyn(&self.keys[m])),
                StageInput::LabelsMask => {
                    inp.push(mark(5, &mb.labels));
                    inp.push(mark(6, &mb.mask));
                }
            }
        }
        Ok(inp)
    }
}

/// Bits reserved for the per-micro-batch static input slot in the
/// device-resident cache key (slots 0..=6 above).
const STATIC_SLOT_BITS: u64 = 3;

/// Send over a stage link, surfacing the failure instead of dropping it:
/// a send only fails when the peer worker exited (bounded sends block,
/// they don't fail), so the error is a typed [`EngineError::LinkClosed`]
/// and the epoch-level triage reports the peer's own error as the root
/// cause.
fn send_link(
    tx: &LinkTx,
    m: usize,
    t: HostTensor,
    stage: usize,
    what: &'static str,
) -> Result<()> {
    tx.send((m, t)).map_err(|_| {
        anyhow::Error::new(EngineError::LinkClosed {
            stage,
            micro_batch: m,
            what,
        })
    })
}

/// Receive a specific micro-batch from a stage link. The two shipped
/// schedules are per-direction FIFO on both ends (the `Schedule`
/// contract), so arrivals already match consumption order and the
/// buffer stays empty — it exists so a custom `Schedule` that consumes
/// a direction out of order still executes correctly instead of
/// deadlocking on a strict in-order recv.
struct OrderedInbox {
    rx: Receiver<Msg>,
    pending: BTreeMap<usize, HostTensor>,
}

impl OrderedInbox {
    fn new(rx: Receiver<Msg>) -> OrderedInbox {
        OrderedInbox { rx, pending: BTreeMap::new() }
    }

    /// Receive micro-batch `m`. With a watchdog, a wait longer than the
    /// timeout fails with [`EngineError::StageTimeout`] — the upstream
    /// peer stalled without closing the channel — instead of blocking
    /// forever; the timeout window restarts on every arrival (progress
    /// resets the watchdog).
    fn recv(
        &mut self,
        m: usize,
        stage: usize,
        what: &'static str,
        watchdog: Option<Duration>,
    ) -> Result<HostTensor> {
        if let Some(t) = self.pending.remove(&m) {
            return Ok(t);
        }
        let start = Instant::now();
        loop {
            let msg = match watchdog {
                None => self.rx.recv().map_err(|_| EngineError::LinkClosed {
                    stage,
                    micro_batch: m,
                    what,
                }),
                Some(d) => match self.rx.recv_timeout(d) {
                    Ok(v) => Ok(v),
                    Err(RecvTimeoutError::Timeout) => {
                        // Post-mortem breadcrumb on this stage's lane —
                        // a chaos-run timeline shows exactly where the
                        // watchdog tripped without reading any logs.
                        crate::trace::instant(
                            "watchdog_fire",
                            &[("stage", stage as i64), ("mb", m as i64)],
                        );
                        crate::metrics::registry::global().inc("watchdog_fires_total");
                        Err(EngineError::StageTimeout {
                            stage,
                            micro_batch: m,
                            what,
                            waited_s: start.elapsed().as_secs_f64(),
                        })
                    }
                    Err(RecvTimeoutError::Disconnected) => Err(EngineError::LinkClosed {
                        stage,
                        micro_batch: m,
                        what,
                    }),
                },
            };
            let (i, t) = msg?;
            if i == m {
                return Ok(t);
            }
            self.pending.insert(i, t);
        }
    }
}

/// acc += delta, elementwise, over parallel tensor lists.
fn accumulate(acc: &mut [HostTensor], delta: &[HostTensor]) -> Result<()> {
    anyhow::ensure!(acc.len() == delta.len(), "grad arity mismatch");
    for (a, d) in acc.iter_mut().zip(delta) {
        let d = d.as_f32()?;
        let a = a.as_f32_mut()?;
        anyhow::ensure!(a.len() == d.len(), "grad size mismatch");
        for (x, y) in a.iter_mut().zip(d) {
            *x += y;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_sums() {
        let mut acc = vec![HostTensor::zeros_f32(vec![3])];
        let d = vec![HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0])];
        accumulate(&mut acc, &d).unwrap();
        accumulate(&mut acc, &d).unwrap();
        assert_eq!(acc[0].as_f32().unwrap(), &[2.0, 4.0, 6.0]);
    }

    #[test]
    fn accumulate_rejects_mismatch() {
        let mut acc = vec![HostTensor::zeros_f32(vec![3])];
        let d = vec![HostTensor::zeros_f32(vec![4])];
        assert!(accumulate(&mut acc, &d).is_err());
    }

    #[test]
    fn mb_source_shared_repeats_one_microbatch() {
        let mb = Microbatch {
            id: 7,
            nodes: vec![0, 1],
            x: HostTensor::zeros_f32(vec![2, 1]),
            graph: vec![],
            labels: HostTensor::s32(vec![2], vec![0, 0]),
            mask: HostTensor::f32(vec![2], vec![1.0, 1.0]),
            cut_edges: 0,
        };
        let src = MbSource::Shared(&mb, 5);
        assert_eq!(src.len(), 5);
        for m in 0..5 {
            assert_eq!(src.get(m).id, 7);
        }
        let slice = [mb.clone()];
        let src = MbSource::PerBatch(&slice);
        assert_eq!(src.len(), 1);
        assert_eq!(src.get(0).id, 7);
    }

    #[test]
    fn ordered_inbox_buffers_out_of_order_arrivals() {
        let (tx, rx) = mpsc::channel::<Msg>();
        tx.send((1, HostTensor::scalar_f32(1.0))).unwrap();
        tx.send((0, HostTensor::scalar_f32(0.0))).unwrap();
        tx.send((2, HostTensor::scalar_f32(2.0))).unwrap();
        let mut inbox = OrderedInbox::new(rx);
        for m in 0..3 {
            let t = inbox.recv(m, 0, "activation", None).unwrap();
            assert_eq!(t.scalar_value().unwrap(), m as f32);
        }
    }

    #[test]
    fn ordered_inbox_reports_closed_channel() {
        let (tx, rx) = mpsc::channel::<Msg>();
        drop(tx);
        let mut inbox = OrderedInbox::new(rx);
        let err = inbox.recv(0, 2, "activation", None).unwrap_err().to_string();
        assert!(err.contains("channel closed"), "{err}");
        assert!(err.contains("stage 2"), "{err}");
    }

    #[test]
    fn ordered_inbox_times_out_with_watchdog() {
        let (tx, rx) = mpsc::channel::<Msg>();
        let mut inbox = OrderedInbox::new(rx);
        let err = inbox
            .recv(4, 1, "activation", Some(Duration::from_millis(40)))
            .unwrap_err();
        let ee = err.downcast_ref::<EngineError>().expect("typed EngineError");
        assert!(
            matches!(
                ee,
                EngineError::StageTimeout { stage: 1, micro_batch: 4, .. }
            ),
            "{ee:?}"
        );
        assert!(err.to_string().contains("timed out"), "{err}");
        drop(tx);
    }

    #[test]
    fn ordered_inbox_watchdog_resets_on_progress() {
        // Arrivals of other micro-batches count as progress: each one
        // restarts the timeout window, so a steady out-of-order stream
        // never trips the watchdog.
        let (tx, rx) = mpsc::channel::<Msg>();
        let feeder = std::thread::spawn(move || {
            for i in 1..4usize {
                std::thread::sleep(Duration::from_millis(20));
                tx.send((i, HostTensor::scalar_f32(i as f32))).unwrap();
            }
            std::thread::sleep(Duration::from_millis(20));
            tx.send((0, HostTensor::scalar_f32(0.0))).unwrap();
        });
        let mut inbox = OrderedInbox::new(rx);
        let t = inbox
            .recv(0, 0, "activation", Some(Duration::from_millis(250)))
            .unwrap();
        assert_eq!(t.scalar_value().unwrap(), 0.0);
        feeder.join().unwrap();
    }

    #[test]
    fn engine_error_classification() {
        // The FULL four-variant classification table. The fleet's retry
        // loop re-runs is_transient errors and treats everything else
        // as replica death, so a variant landing in the wrong column is
        // a serving-availability bug: a retried StagePanic would loop a
        // deterministic crash forever, a non-retried InjectedFault
        // would fail chaos runs that are retryable by construction.
        let panic = EngineError::StagePanic {
            stage: 0,
            message: "boom".to_string(),
        };
        let timeout = EngineError::StageTimeout {
            stage: 1,
            micro_batch: 0,
            what: "activation",
            waited_s: 0.5,
        };
        let closed = EngineError::LinkClosed {
            stage: 1,
            micro_batch: 0,
            what: "activation",
        };
        let injected = EngineError::InjectedFault { stage: 2, micro_batch: 1 };
        // (variant, is_disconnect, is_transient) — one row per variant;
        // adding an EngineError variant must extend this table.
        let table: Vec<(EngineError, bool, bool)> = vec![
            (panic, false, false),
            (timeout, false, false),
            (closed, true, false),
            (injected.clone(), false, true),
        ];
        for (e, disconnect, transient) in &table {
            assert_eq!(e.is_disconnect(), *disconnect, "{e:?}");
            assert_eq!(e.is_transient(), *transient, "{e:?}");
        }
        // Exactly one variant is retry-worthy, exactly one is
        // link-teardown collateral.
        assert_eq!(table.iter().filter(|(e, ..)| e.is_transient()).count(), 1);
        assert_eq!(table.iter().filter(|(e, ..)| e.is_disconnect()).count(), 1);
        // The triage in execute() keys on the typed chain surviving a
        // context wrap.
        let wrapped = anyhow::Error::new(injected.clone()).context("pipeline stage failed");
        assert!(wrapped
            .chain()
            .any(|c| c.downcast_ref::<EngineError>().is_some_and(EngineError::is_transient)));
        // A non-transient error stays non-transient through the wrap —
        // the retry loop must not resurrect it.
        let wrapped = anyhow::Error::new(EngineError::StagePanic {
            stage: 3,
            message: "deterministic bug".to_string(),
        })
        .context("pipeline stage failed");
        assert!(!wrapped
            .chain()
            .any(|c| c.downcast_ref::<EngineError>().is_some_and(EngineError::is_transient)));
    }

    #[test]
    fn panic_message_renders_common_payloads() {
        let p = std::panic::catch_unwind(|| panic!("boom")).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "boom");
        let p = std::panic::catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "boom 7");
    }

    #[test]
    fn send_link_reports_closed_channel() {
        let (tx, rx) = mpsc::channel::<Msg>();
        drop(rx);
        let tx = LinkTx::Unbounded(tx);
        let err = send_link(&tx, 3, HostTensor::scalar_f32(0.0), 1, "cotangent")
            .unwrap_err()
            .to_string();
        assert!(err.contains("channel closed"), "{err}");
        assert!(err.contains("micro-batch 3"), "{err}");
        let (tx, rx) = mpsc::sync_channel::<Msg>(SERVE_LINK_DEPTH);
        drop(rx);
        let tx = LinkTx::Bounded(tx);
        let err = send_link(&tx, 0, HostTensor::scalar_f32(0.0), 2, "activation")
            .unwrap_err()
            .to_string();
        assert!(err.contains("channel closed"), "{err}");
    }

    #[test]
    fn bounded_link_applies_backpressure_but_delivers_fifo() {
        // A bounded serve link holds at most SERVE_LINK_DEPTH queued
        // messages; a consumer draining them unblocks the producer and
        // sees strict FIFO.
        let (tx, rx) = mpsc::sync_channel::<Msg>(SERVE_LINK_DEPTH);
        let tx = LinkTx::Bounded(tx);
        let producer = std::thread::spawn(move || {
            for m in 0..8usize {
                send_link(&tx, m, HostTensor::scalar_f32(m as f32), 0, "activation")
                    .unwrap();
            }
        });
        let mut inbox = OrderedInbox::new(rx);
        for m in 0..8usize {
            let t = inbox.recv(m, 1, "activation", None).unwrap();
            assert_eq!(t.scalar_value().unwrap(), m as f32);
        }
        producer.join().unwrap();
    }
}
