//! E10 — hybrid data×pipe parallelism: replicated pipelines over graph
//! partitions (`--replicas R`) against the pipe-only baseline on the
//! *same total data*, with the host's thread-per-replica concurrency
//! measured against its closed-form model.
//!
//! All rows share one fixed total partition (R × chunks/replica =
//! `total`), so every configuration trains the identical micro-batch
//! set and the identical per-micro-batch forwards — the rows differ
//! only in how gradients are summed (the deterministic tree all-reduce
//! association) and in how the work maps onto devices. The `dLoss vs
//! R=1` column is therefore expected to sit at float-rounding scale —
//! and the sequential (`--replica-threads 1`) and concurrent (auto)
//! runs of each row are **bit-identical**, so one loss column covers
//! both.
//!
//! Each row prints the measured sequential and concurrent host epochs
//! and their ratio next to the modeled host-concurrency speedup
//! (`simulator::host_concurrency_speedup`: replica waves + Amdahl on
//! the serial all-reduce), plus two DGX projections: the pipe-only
//! baseline and the row's own hybrid layout (R nodes × S V100s with
//! the gradient tree on the modeled inter-node link).

use anyhow::Result;

use crate::metrics::Table;
use crate::pipeline::PipelineSpec;
use crate::simulator::{host_concurrency_speedup, Scenarios};
use crate::util::par::available_threads;

use super::{framework_label, schedule_label, BenchCtx};

/// E10: (replicas, chunks) factorisations of one fixed partition —
/// pipe-only vs hybrid DGX projections next to measured epochs.
pub fn bench_hybrid(ctx: &BenchCtx) -> Result<String> {
    let backend = "ell";
    let total = ctx
        .cfg
        .pipeline
        .chunks
        .iter()
        .copied()
        .max()
        .unwrap_or(4)
        .max(2);
    // Every (R, chunks/replica) factorisation of the same total
    // partition: for total = 4 that is (1,4), (2,2), (4,1).
    let configs: Vec<(usize, usize)> = (1..=total)
        .filter(|r| total % r == 0)
        .map(|r| (r, total / r))
        .collect();

    let spec = PipelineSpec::gat4();
    let baseline =
        ctx.pipeline_run_replicas(backend, total, false, false, ctx.prep, 1, 1)?;
    let single = ctx.single_run("pubmed", backend)?;
    let scen = Scenarios::calibrate_from_cpu(
        &ctx.engine.manifest,
        &format!("pubmed_{backend}_train_step"),
        single.timing.avg_epoch_s(),
    )?;
    let pipe_only = scen.hybrid_epoch(
        &spec,
        "pubmed",
        backend,
        1,
        total,
        true,
        baseline.host_rebuild_per_chunk_s,
        ctx.schedule.as_ref(),
        ctx.prep,
    )?;

    let mut table = Table::new(&[
        "Replicas",
        "Chunks/rep",
        "Epoch seq (s)",
        "Epoch conc (s)",
        "Host speedup",
        "Host speedup (model)",
        "allreduce_s (host)",
        "dLoss vs R=1",
        "DGX pipe-only (s, sim)",
        "DGX hybrid (s, sim)",
    ]);
    let mut csv = String::from(
        "replicas,chunks_per_replica,host_threads,avg_epoch_seq_s,avg_epoch_conc_s,\
         host_speedup,host_speedup_model,allreduce_s,replica_cpu_s,final_loss,\
         dloss_vs_r1,test_acc_full,dgx_pipe_only_s,dgx_hybrid_s,dgx_allreduce_s\n",
    );

    let epochs = ctx.epochs.max(1) as f64;
    for &(r, chunks) in &configs {
        let seq =
            ctx.pipeline_run_replicas(backend, chunks, false, false, ctx.prep, r, 1)?;
        // Concurrent run (auto threads). R=1 resolves to one thread —
        // the identical run — so reuse the sequential result instead of
        // training the same configuration twice.
        let conc = if r == 1 {
            seq.clone()
        } else {
            ctx.pipeline_run_replicas(backend, chunks, false, false, ctx.prep, r, 0)?
        };
        let threads = r.min(available_threads());
        let dloss = seq.pipeline_eval.train_loss - baseline.pipeline_eval.train_loss;
        // Model inputs from the measured sequential run: one replica's
        // epoch seconds and the per-epoch reduction cost.
        let e_rep = seq.timing.replica_cpu_s / epochs / r as f64;
        let ar = seq.timing.allreduce_s / epochs;
        let measured =
            seq.timing.avg_epoch_s() / conc.timing.avg_epoch_s().max(1e-12);
        let modeled = host_concurrency_speedup(r, threads, e_rep, ar);
        let hybrid = scen.hybrid_epoch(
            &spec,
            "pubmed",
            backend,
            r,
            chunks,
            true,
            seq.host_rebuild_per_chunk_s,
            ctx.schedule.as_ref(),
            ctx.prep,
        )?;
        table.row(&[
            format!("{r}"),
            format!("{chunks}"),
            format!("{:.4}", seq.timing.avg_epoch_s()),
            format!("{:.4}", conc.timing.avg_epoch_s()),
            format!("{measured:.2}x"),
            format!("{modeled:.2}x (T={threads})"),
            format!("{:.5}", conc.timing.allreduce_s),
            format!("{dloss:+.2e}"),
            format!("{:.5}", pipe_only.epoch_s),
            format!("{:.5}", hybrid.epoch_s),
        ]);
        csv.push_str(&format!(
            "{r},{chunks},{threads},{:.5},{:.5},{measured:.4},{modeled:.4},{:.6},{:.6},{:.6},{dloss:.6e},{:.4},{:.6},{:.6},{:.6e}\n",
            seq.timing.avg_epoch_s(),
            conc.timing.avg_epoch_s(),
            conc.timing.allreduce_s,
            conc.timing.replica_cpu_s,
            seq.pipeline_eval.train_loss,
            seq.full_eval.test_acc,
            pipe_only.epoch_s,
            hybrid.epoch_s,
            hybrid.allreduce_s,
        ));
    }

    ctx.write_csv("hybrid.csv", &csv)?;
    Ok(format!(
        "Hybrid data×pipe — {} {} total-partition={total} {} prep={} ({} epochs, {} cores)\n{}\n\
         shape check: every row trains the same {total}-way partition, so dLoss \
         stays at float-rounding scale — and each row's sequential and concurrent \
         runs are bit-identical (the sharded tree all-reduce preserves the \
         per-element association), so the Host columns differ ONLY in wall-clock; \
         the model column prices replica waves (ceil(R/T)) plus Amdahl on the \
         serial reduction, and the hybrid DGX column trades a shorter per-replica \
         drain against ceil(log2 R) gradient rounds on the inter-node link\n",
        framework_label(backend),
        ctx.cfg.pipeline.pipeline_dataset,
        schedule_label(ctx.schedule.name()),
        ctx.prep.name(),
        ctx.epochs,
        available_threads(),
        table.render()
    ))
}
