"""Pipeline-stage semantics: the GPipe gradient-equivalence invariant.

The coordinator's whole correctness story rests on: running the staged
fwd chain, the fused s3loss backward, and the rematerialising stage
backwards — then normalising by the accumulated mask count — must equal
``jax.value_and_grad`` of the monolithic loss.  These tests execute the
exact call sequence rust/src/pipeline performs, in Python, against the
same stage functions that aot.py lowers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import stages as S
from tests.conftest import build_graph, tiny_profile


def _staged_grads(ds, mc, backend, p, x, gflat, labels, mask, key):
    """Replicate the coordinator's fwd/bwd chain for ONE micro-batch."""
    fns = S.stage_fns(ds, mc, backend)
    p1 = [p[n] for n in ("w1", "a1_src", "a1_dst", "b1")]
    p2 = [p[n] for n in ("w2", "a2_src", "a2_dst", "b2")]

    (h0,) = fns["s0_fwd"](*p1, x, *gflat, key)
    (h1,) = fns["s1_fwd"](h0, key)
    (lg,) = fns["s2_fwd"](*p2, h1, *gflat, key)
    (logp,) = fns["s3_fwd"](lg)

    s, cnt, dlg = fns["s3loss_bwd"](lg, labels, mask)
    *dp2, dh1 = fns["s2_bwd"](*p2, h1, *gflat, key, dlg)
    (dh0,) = fns["s1_bwd"](h0, key, dh1)
    dp1 = fns["s0_bwd"](*p1, x, *gflat, key, dh0)

    grads = dict(zip(("w1", "a1_src", "a1_dst", "b1"), dp1))
    grads.update(dict(zip(("w2", "a2_src", "a2_dst", "b2"), dp2)))
    return float(s), float(cnt), grads, logp


@pytest.mark.parametrize("backend", ["ell", "edgewise"])
def test_pipeline_matches_monolith(tiny, model_config, backend):
    """Staged grads (sum-normalised) == train_step grads (mean) exactly."""
    ds, x, labels, gell, gcoo = tiny
    mc = model_config
    graph = gell if backend == "ell" else gcoo
    gflat = tuple(graph.values())
    p = M.init_params(ds, mc, seed=0)
    mask = (np.random.default_rng(2).random(ds.nodes) > 0.5).astype(np.float32)
    mask = jnp.asarray(mask)
    key = jnp.asarray([3, 5], jnp.uint32)

    s, cnt, grads, _ = _staged_grads(ds, mc, backend, p, x, gflat, labels, mask, key)

    step = S.make_train_step(ds, mc, backend)
    flat = [p[n] for n in M.PARAM_NAMES]
    out = step(*flat, x, *gflat, labels, mask, key)
    loss_mono = float(out[0])
    grads_mono = dict(zip(M.PARAM_NAMES, out[1:]))

    np.testing.assert_allclose(s / cnt, loss_mono, rtol=1e-5)
    for n in M.PARAM_NAMES:
        np.testing.assert_allclose(
            grads[n] / cnt, grads_mono[n], rtol=5e-4, atol=1e-6, err_msg=n
        )


def test_chunked_accumulation_matches_monolith_when_lossless(model_config):
    """2-chunk pipeline == monolith when the split loses no edges.

    Build a graph whose edges never cross the chunk boundary; sequential
    chunking is then lossless and GPipe's accumulate-then-normalise must
    reproduce the full-batch gradient. This is the Python twin of the Rust
    proptest ``chunk_invariance``.
    """
    mc = model_config
    ds = tiny_profile(n=40, edges=0, features=12, classes=3, k=4)
    rng = np.random.default_rng(0)
    # Edges only within halves [0,20) and [20,40).
    half = ds.nodes // 2
    gell_idx = np.zeros((ds.nodes, ds.ell_k), np.int32)
    gell_mask = np.zeros((ds.nodes, ds.ell_k), np.float32)
    for i in range(ds.nodes):
        lo, hi = (0, half) if i < half else (half, ds.nodes)
        nbrs = [i] + list(rng.integers(lo, hi, size=2))
        nbrs = list(dict.fromkeys(nbrs))[: ds.ell_k]
        gell_idx[i, : len(nbrs)] = nbrs
        gell_mask[i, : len(nbrs)] = 1.0

    x = jnp.asarray(rng.normal(size=(ds.nodes, ds.features)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, ds.classes, ds.nodes).astype(np.int32))
    mask = jnp.ones((ds.nodes,), jnp.float32)
    p = M.init_params(ds, mc, seed=1)

    # Monolith, but evaluated per-chunk with the SAME per-chunk keys the
    # pipeline uses (dropout masks are per-micro-batch in GPipe, so exact
    # equality holds only at matching keys; using deterministic=True via
    # zero dropout would hide key-plumbing bugs, so we compare the staged
    # two-chunk run against an explicit two-chunk monolithic computation).
    def chunk_inputs(lo, hi):
        idx = gell_idx[lo:hi].copy()
        m = gell_mask[lo:hi].copy()
        idx = idx - lo  # re-index into the chunk (all nbrs are in-chunk)
        return (
            jnp.asarray(idx),
            jnp.asarray(m),
            x[lo:hi],
            labels[lo:hi],
            mask[lo:hi],
        )

    total_s, total_cnt = 0.0, 0.0
    acc = {n: 0.0 for n in M.PARAM_NAMES}
    for ci, (lo, hi) in enumerate(((0, half), (half, ds.nodes))):
        ii, mm, xx, ll, kk_mask = chunk_inputs(lo, hi)
        key = jnp.asarray([11, ci], jnp.uint32)
        s, cnt, grads, _ = _staged_grads(
            ds, mc, "ell", p, xx, (ii, mm), ll, kk_mask, key
        )
        total_s += s
        total_cnt += cnt
        for n in M.PARAM_NAMES:
            acc[n] = acc[n] + grads[n]

    # Reference: sum of per-chunk monolithic sum-losses, same keys.
    def ref_loss(p_dict):
        tot = 0.0
        for ci, (lo, hi) in enumerate(((0, half), (half, ds.nodes))):
            ii, mm, xx, ll, kk_mask = chunk_inputs(lo, hi)
            key = jnp.asarray([11, ci], jnp.uint32)
            logp = M.full_forward(
                p_dict, xx, {"ell_idx": ii, "ell_mask": mm}, "ell", mc,
                ds.classes, key, deterministic=False,
            )
            s, _ = M.nll_loss(logp, ll, kk_mask)
            tot = tot + s
        return tot

    want_loss = float(ref_loss(p))
    want_grads = jax.grad(ref_loss)(p)
    np.testing.assert_allclose(total_s, want_loss, rtol=1e-5)
    assert total_cnt == ds.nodes
    for n in M.PARAM_NAMES:
        np.testing.assert_allclose(
            acc[n], want_grads[n], rtol=5e-4, atol=1e-6, err_msg=n
        )


@pytest.mark.parametrize("backend", ["ell", "edgewise"])
def test_eval_stage_chain_matches_full_forward(tiny, model_config, backend):
    """The serving artifacts' exact contract: composing the staged
    deterministic forwards (s0_eval -> s1_eval -> s2_eval -> s3)
    reproduces the fused deterministic evaluation — the same functions
    eval_fwd lowers — so the Rust serve path computes full_eval's math."""
    ds, x, labels, gell, gcoo = tiny
    mc = model_config
    graph = gell if backend == "ell" else gcoo
    gflat = tuple(graph.values())
    p = M.init_params(ds, mc, seed=0)
    p1 = [p[n] for n in ("w1", "a1_src", "a1_dst", "b1")]
    p2 = [p[n] for n in ("w2", "a2_src", "a2_dst", "b2")]

    fns = S.stage_fns(ds, mc, backend)
    (h0,) = fns["s0_eval_fwd"](*p1, x, *gflat)
    (h1,) = fns["s1_eval_fwd"](h0)
    (lg,) = fns["s2_eval_fwd"](*p2, h1, *gflat)
    (logp,) = fns["s3_fwd"](lg)

    zero_key = jnp.zeros((2,), jnp.uint32)
    want = M.full_forward(
        p, x, graph, backend, mc, ds.classes, zero_key, deterministic=True
    )
    np.testing.assert_array_equal(np.asarray(logp), np.asarray(want))

    # And the fused eval entry point agrees too (same composition).
    flat = [p[n] for n in M.PARAM_NAMES]
    (via_eval,) = S.make_eval_fwd(ds, mc, backend)(*flat, x, *gflat)
    np.testing.assert_array_equal(np.asarray(via_eval), np.asarray(want))


def test_eval_stage_specs_drop_the_key(model_config):
    """Serving forwards take the training layouts minus the dropout key."""
    from compile.configs import load_datasets

    ds = load_datasets()["pubmed"]
    mc = model_config
    for backend in M.BACKENDS:
        sp = S.stage_specs(ds, mc, backend, 1)
        for kind in ("s0", "s1", "s2"):
            train = sp[f"{kind}_fwd"]
            evalv = sp[f"{kind}_eval_fwd"]
            assert [n for n, _ in train if n != "key"] == [n for n, _ in evalv]
            assert all(n != "key" for n, _ in evalv)
            for (_, a), (_, b) in zip(
                [t for t in train if t[0] != "key"], evalv
            ):
                assert a.shape == b.shape and a.dtype == b.dtype


def test_s3loss_bwd_gradient_is_softmax_minus_onehot(model_config):
    """Analytic check: d(sum NLL)/d logits = softmax(logits) - onehot."""
    rng = np.random.default_rng(0)
    lg = jnp.asarray(rng.normal(size=(10, 4)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 4, 10).astype(np.int32))
    mask = jnp.asarray((rng.random(10) > 0.3).astype(np.float32))
    s, cnt, dlg = S.make_s3loss_bwd()(lg, labels, mask)
    p = jax.nn.softmax(lg, axis=1)
    onehot = jax.nn.one_hot(labels, 4)
    want = (p - onehot) * mask[:, None]
    np.testing.assert_allclose(dlg, want, rtol=1e-5, atol=1e-6)
    assert float(cnt) == float(mask.sum())


def test_stage_specs_shapes_consistent(model_config):
    """Every bwd spec's cotangent matches the fwd output shape; chunk
    capacities shrink with chunk count."""
    from compile.configs import load_datasets

    ds = load_datasets()["pubmed"]
    mc = model_config
    for backend in M.BACKENDS:
        prev_n = None
        for k in (1, 2, 3, 4):
            sp = S.stage_specs(ds, mc, backend, k)
            n_c = ds.chunk_nodes(k)
            if prev_n is not None:
                assert n_c <= prev_n
            prev_n = n_c
            # s0_fwd output (h) feeds s1_fwd input
            assert sp["s1_fwd"][0][1].shape == (n_c, mc.heads * mc.hidden)
            # s2_bwd cotangent matches s2_fwd output (logits)
            assert sp["s2_bwd"][-1][1].shape == (n_c, ds.classes)
            # s0_bwd cotangent matches s0_fwd output
            assert sp["s0_bwd"][-1][1].shape == (n_c, mc.heads * mc.hidden)


def test_remat_bwd_uses_same_dropout_as_fwd(tiny, model_config):
    """The rematerialising backward must regenerate the SAME dropout masks
    as the forward (same key): finite-difference the staged loss along one
    parameter direction and compare with the staged gradient."""
    ds, x, labels, gell, _ = tiny
    mc = model_config
    gflat = tuple(gell.values())
    p = M.init_params(ds, mc, seed=3)
    mask = jnp.ones((ds.nodes,), jnp.float32)
    key = jnp.asarray([8, 2], jnp.uint32)

    s, cnt, grads, _ = _staged_grads(ds, mc, "ell", p, x, gflat, labels, mask, key)

    def staged_loss(p_dict):
        fns = S.stage_fns(ds, mc, "ell")
        p1 = [p_dict[n] for n in ("w1", "a1_src", "a1_dst", "b1")]
        p2 = [p_dict[n] for n in ("w2", "a2_src", "a2_dst", "b2")]
        (h0,) = fns["s0_fwd"](*p1, x, *gflat, key)
        (h1,) = fns["s1_fwd"](h0, key)
        (lg,) = fns["s2_fwd"](*p2, h1, *gflat, key)
        (logp,) = fns["s3_fwd"](lg)
        ss, _ = M.nll_loss(logp, labels, mask)
        return float(ss)

    eps = 1e-3
    rng = np.random.default_rng(0)
    d = jnp.asarray(rng.normal(size=p["b2"].shape).astype(np.float32))
    pp = dict(p)
    pp["b2"] = p["b2"] + eps * d
    pm = dict(p)
    pm["b2"] = p["b2"] - eps * d
    fd = (staged_loss(pp) - staged_loss(pm)) / (2 * eps)
    analytic = float(jnp.vdot(grads["b2"], d))
    np.testing.assert_allclose(fd, analytic, rtol=2e-2, atol=1e-3)
