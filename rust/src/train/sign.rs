//! E9 — SIGN chunked training: the paper's §8 "best batching approach".
//!
//! Representations are precomputed on the host (`data::sign_features`),
//! so GPipe-style sequential micro-batching is **lossless by
//! construction**: the trainable model is a plain MLP, and chunking a
//! row-independent model preserves gradients exactly. This trainer runs
//! the same sequential chunker that collapses the GAT's accuracy
//! (Fig 4) and demonstrates no degradation — closing the loop on the
//! paper's conjecture.


use anyhow::Result;

use crate::batching::{Chunker, SequentialChunker};
use crate::config::ModelConfig;
use crate::data::{sign_features, Dataset};
use crate::metrics::{Curve, RunTiming, Timer};
use crate::optim::{Adam, Optimizer};
use crate::runtime::{Engine, HostTensor};
use crate::util::rng::Rng;

pub const SIGN_HOPS: usize = 2;
pub const SIGN_HIDDEN: usize = 64;
const SIGN_PARAMS: [&str; 4] = ["sw1", "sb1", "sw2", "sb2"];

pub struct SignTrainer<'e> {
    engine: &'e Engine,
    dataset: &'e Dataset,
    pub chunks: usize,
    pub seed: u64,
}

#[derive(Debug)]
pub struct SignResult {
    pub timing: RunTiming,
    pub train_loss: Curve,
    pub val_acc: f64,
    pub test_acc: f64,
    pub train_acc: f64,
    /// Host seconds spent in the one-off representation precompute.
    pub precompute_s: f64,
}

impl<'e> SignTrainer<'e> {
    pub fn new(engine: &'e Engine, dataset: &'e Dataset, chunks: usize) -> Self {
        SignTrainer { engine, dataset, chunks, seed: 0 }
    }

    fn init_params(&self, d_in: usize, classes: usize) -> Vec<HostTensor> {
        let mut rng = Rng::new(self.seed ^ 0x51_67);
        let mut glorot = |shape: Vec<usize>| {
            let limit = (6.0 / (shape[0] + shape[1]) as f64).sqrt();
            let n: usize = shape.iter().product();
            let data = (0..n).map(|_| rng.range_f64(-limit, limit) as f32).collect();
            HostTensor::f32(shape, data)
        };
        vec![
            glorot(vec![d_in, SIGN_HIDDEN]),
            HostTensor::zeros_f32(vec![SIGN_HIDDEN]),
            glorot(vec![SIGN_HIDDEN, classes]),
            HostTensor::zeros_f32(vec![classes]),
        ]
    }

    pub fn train(&self, mc: &ModelConfig, epochs: usize) -> Result<SignResult> {
        let ds = self.dataset;
        let p = &ds.profile;
        let n = p.nodes;
        let d_in = (SIGN_HOPS + 1) * p.features;

        // One-off host precompute — the SIGN trade: graph work moves out
        // of the training loop entirely.
        let pre = Timer::start();
        let table = sign_features(&ds.graph, &ds.features, p.features, SIGN_HOPS);
        let precompute_s = pre.secs();

        let train_mask = ds.splits.train_mask(n);
        let plan = SequentialChunker.plan(&ds.graph, self.chunks);
        let n_c = p.chunk_nodes(self.chunks);

        // Pre-gather per-chunk rows of the precomputed table (lossless —
        // no graph structure involved any more).
        let mut chunk_inputs = Vec::new();
        for chunk in &plan.chunks {
            let mut x = vec![0f32; n_c * d_in];
            for (i, &v) in chunk.iter().enumerate() {
                x[i * d_in..(i + 1) * d_in]
                    .copy_from_slice(&table[v as usize * d_in..(v as usize + 1) * d_in]);
            }
            chunk_inputs.push((
                HostTensor::f32(vec![n_c, d_in], x),
                HostTensor::s32(vec![n_c], ds.gather_labels(chunk, n_c)),
                HostTensor::f32(vec![n_c], ds.gather_mask(&train_mask, chunk, n_c)),
            ));
        }

        let step = self
            .engine
            .executable(&format!("{}_sign_c{}_train_step", p.name, self.chunks))?;
        let eval = self
            .engine
            .executable(&format!("{}_sign_eval_fwd", p.name))?;

        let mut params = self.init_params(d_in, p.classes);
        let mut adam = Adam::from_config(mc);
        let mut timing = RunTiming { epochs, ..Default::default() };
        let mut train_loss = Curve::default();

        for epoch in 1..=epochs {
            let t = Timer::start();
            let mut loss_sum = 0f64;
            let mut count = 0f64;
            let mut acc: Vec<HostTensor> = params
                .iter()
                .map(|pp| HostTensor::zeros_f32(pp.shape().to_vec()))
                .collect();
            for (m, (x, labels, mask)) in chunk_inputs.iter().enumerate() {
                let mut inputs = params.clone();
                inputs.push(x.clone());
                inputs.push(labels.clone());
                inputs.push(mask.clone());
                inputs.push(HostTensor::key(
                    self.seed as u32 + m as u32,
                    epoch as u32,
                ));
                let out = step.run(&inputs)?;
                loss_sum += out[0].scalar_value()? as f64;
                count += out[1].scalar_value()? as f64;
                for (a, g) in acc.iter_mut().zip(&out[2..]) {
                    let a = a.as_f32_mut()?;
                    for (x, y) in a.iter_mut().zip(g.as_f32()?) {
                        *x += y;
                    }
                }
            }
            let scale = 1.0 / count.max(1.0) as f32;
            for g in acc.iter_mut() {
                for v in g.as_f32_mut()? {
                    *v *= scale;
                }
            }
            adam.step(&mut params, &acc)?;
            train_loss.push(epoch, loss_sum / count.max(1.0));
            let dt = t.secs();
            timing.per_epoch_s.push(dt);
            if epoch == 1 {
                timing.epoch1_s = dt;
            } else {
                timing.epochs_rest_s += dt;
            }
        }

        // Full-table deterministic eval.
        let mut inputs = params.clone();
        inputs.push(HostTensor::f32(vec![n, d_in], table));
        let logp = eval.run(&inputs)?;
        let logp = logp[0].as_f32()?;
        let acc_of = |mask: &[f32]| {
            crate::train::accuracy(logp, &ds.labels, mask, p.classes)
        };
        Ok(SignResult {
            timing,
            train_loss,
            train_acc: acc_of(&train_mask),
            val_acc: acc_of(&ds.splits.val_mask(n)),
            test_acc: acc_of(&ds.splits.test_mask(n)),
            precompute_s,
        })
    }
}

/// Manifest param-name order for the SIGN MLP (used by tests).
pub fn sign_param_names() -> &'static [&'static str] {
    &SIGN_PARAMS
}
