//! The pipeline trainer: per-epoch orchestration around the engine.
//!
//! Reproduces the paper's experimental procedure exactly:
//!   * `chunks = 1`, `rebuild = false`  →  Table 2's "Chunk = 1*" rows
//!     (full graph defined inside the model; no tuple passing, no host
//!     re-build);
//!   * `chunks = 1..4`, `rebuild = true` →  the tuple-passing adaptation:
//!     node tensor chunked sequentially, sub-graphs re-built on the host
//!     every epoch (timed into `RunTiming::rebuild_s` — the §7.2
//!     overhead), structure loss reflected in training AND evaluation
//!     through the lossy union graph.
//!
//! The host-prep strategy is selected by [`PrepMode`] (`prep` field):
//! `Paper` keeps the faithful critical-path rebuild above (into pooled
//! buffers); `Cached` builds the micro-batches once per
//! (plan, backend, train-mask) key; `Overlap` rebuilds on a prefetch
//! thread overlapped with pipeline execution. Losses, gradients and
//! final parameters are bitwise identical across modes — only the
//! timing split (`rebuild_s` / `prep_overlap_s` / `transfer_s`) moves.
//!
//! `replicas` (CLI `--replicas`, default 1) adds the second parallelism
//! axis: the chunk planner partitions the node set `replicas * chunks`
//! ways, a [`ReplicaGroup`] trains `chunks` micro-batches per replica,
//! and the per-replica gradient sums are folded by the deterministic
//! tree all-reduce (`optim::allreduce`) before the single Adam step.
//! The R replica epochs execute concurrently on up to
//! `--replica-threads` host threads (default `min(R, cores)`; see
//! `pipeline::replica` for the determinism argument — results are
//! bit-identical to `--replica-threads 1`, the sequential loop).
//! At `replicas == 1` the trainer takes the exact single-pipeline code
//! path — no reduction, no extra clone.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::Result;

use crate::batching::{
    retention_stats, ChunkPlan, Chunker, RetentionStats, SequentialChunker,
};
use crate::config::ModelConfig;
use crate::data::Dataset;
use crate::metrics::{Curve, RunTiming, Timer};
use crate::optim::{Adam, Optimizer};
use crate::runtime::{Engine, HostTensor};
use crate::store::{flat_to_vec, vec_to_flat, Store, TrainCheckpoint};
use crate::train::{
    flatten_params, init_params, unflatten_params, Evaluator,
};
use crate::util::rng::Rng;

use super::chunkprep::{
    lossy_union_from_induced, microbatches_from_induced, Microbatch,
};
use super::engine::PipelineEngine;
use super::prep::{
    spawn_prefetcher, MicrobatchCache, MicrobatchPool, PrefetchMsg, PrepMode,
};
use super::replica::ReplicaGroup;
use super::schedule::{FillDrain, Schedule};
use super::spec::PipelineSpec;

pub struct PipelineTrainer<'e> {
    engine: &'e Engine,
    dataset: &'e Dataset,
    backend: String,
    /// Micro-batches per pipeline instance (the paper's `chunks`).
    pub chunks: usize,
    /// Pipeline replica count (hybrid data×pipe parallelism). The node
    /// set is partitioned `replicas * chunks` ways; replica `r` trains
    /// micro-batches `[r*chunks, (r+1)*chunks)` and gradients are merged
    /// by the deterministic tree all-reduce each epoch. 1 (default) =
    /// the paper's single pipeline, on the exact pre-replica code path.
    pub replicas: usize,
    /// Host worker threads for replica execution (CLI
    /// `--replica-threads`, config key `replica_threads`). 0 (default)
    /// resolves to `min(replicas, cores)`; 1 forces the sequential
    /// replica loop — today's exact code path. Grads/loss/logp are
    /// bit-identical at any value (see `pipeline::replica`); only
    /// wall-clock moves.
    pub replica_threads: usize,
    /// false = the paper's "Chunk = 1*" configuration (graph baked into
    /// the model, no host re-build). Only valid with chunks == 1.
    pub rebuild: bool,
    pub chunker: Box<dyn Chunker + Send + Sync>,
    /// Stage layout to train; defaults to the paper's 4-stage GAT.
    pub spec: PipelineSpec,
    /// Execution order within a step; defaults to GPipe fill-drain.
    /// Gradients are schedule-invariant (FIFO accumulation), so this
    /// only changes timing and peak activation memory.
    pub schedule: Arc<dyn Schedule>,
    /// Host-prep strategy; `Paper` (default) reproduces the §7.2 stall.
    pub prep: PrepMode,
    /// Micro-batch cache for [`PrepMode::Cached`]; share one across
    /// trainers to reuse prepared sets between runs on the same plan.
    pub prep_cache: Arc<MicrobatchCache>,
    pub seed: u64,
    pub eval_every: usize,
    /// Module counts per stage for `spec` (the partitioner's view of
    /// the layout). Only consulted by the `--repartition-check` drift
    /// log; defaults to the canonical gat4 grouping.
    pub balance: Vec<usize>,
    /// After training, fold the measured stage means back into the
    /// partitioner and LOG (never switch) when the DP would now pick a
    /// different split (CLI `--repartition-check`). A mid-run switch
    /// would change artifact kinds and break bitwise replay, so this is
    /// advisory only.
    pub repartition_check: bool,
    /// Crash-safe checkpoint store directory (`--checkpoint-dir`).
    /// `None` disables checkpointing.
    pub checkpoint_dir: Option<PathBuf>,
    /// Publish a checkpoint every K completed epochs
    /// (`--checkpoint-every`; the final epoch always checkpoints when a
    /// store is configured, so 0 = final-only).
    pub checkpoint_every: usize,
    /// Resume from the newest valid checkpoint in the store
    /// (`--resume`). The resumed run is bit-identical to the
    /// uninterrupted run: dropout keys are `(seed, epoch)`-pure and the
    /// checkpoint restores params/Adam/curves/epoch cursor exactly.
    pub resume: bool,
}

#[derive(Debug)]
pub struct PipelineResult {
    pub timing: RunTiming,
    /// Final metrics through the chunk-lossy graph (what the paper's
    /// chunked training loop reports — Figure 4 / Table 2 chunks rows).
    pub pipeline_eval: crate::train::EvalMetrics,
    /// Final metrics through the intact full graph (what the trained
    /// parameters are worth if inference avoids chunking).
    pub full_eval: crate::train::EvalMetrics,
    pub train_loss: Curve,
    /// Training accuracy per epoch from the pipeline's own (stochastic,
    /// chunked) forward outputs — the quantity Figure 2/4 plot.
    pub train_acc: Curve,
    pub val_acc: Curve,
    pub retention: RetentionStats,
    /// Mean per-stage executable seconds (fwd, bwd), for the simulator.
    pub stage_means: Vec<(f64, f64)>,
    pub params: BTreeMap<String, HostTensor>,
}

/// Where each epoch's micro-batches come from (one variant per
/// [`PrepMode`], plus the prepared-once 1*/Cached path).
enum MbFeed<'a> {
    /// Prepared once before the loop (the 1* variant and `Cached` mode).
    Static(&'a [Microbatch]),
    /// `Paper` mode: serial rebuild on the critical path every epoch,
    /// into pooled buffers.
    Rebuild {
        pool: MicrobatchPool,
        ds: &'a Dataset,
        plan: &'a ChunkPlan,
        backend: &'a str,
        train_mask: &'a [f32],
    },
    /// `Overlap` mode: the prefetch thread rebuilds epoch e+1 during e.
    Prefetch(Receiver<PrefetchMsg>),
}

/// Borrowed setup shared by every epoch of one run.
struct EpochCtx<'a> {
    group: &'a ReplicaGroup<'a>,
    evaluator: &'a Evaluator,
    order: &'a [String],
    train_mask: &'a [f32],
    setup_s: f64,
}

/// Mutable accumulation state of one run.
struct TrainAccum {
    flat: Vec<HostTensor>,
    adam: Adam,
    timing: RunTiming,
    train_loss: Curve,
    train_acc: Curve,
    val_acc: Curve,
    stage_fwd_sum: Vec<f64>,
    stage_bwd_sum: Vec<f64>,
    stage_calls: usize,
}

impl<'e> PipelineTrainer<'e> {
    pub fn new(
        engine: &'e Engine,
        dataset: &'e Dataset,
        backend: &str,
        chunks: usize,
    ) -> Self {
        PipelineTrainer {
            engine,
            dataset,
            backend: backend.to_string(),
            chunks,
            replicas: 1,
            replica_threads: 0,
            rebuild: true,
            chunker: Box::new(SequentialChunker),
            spec: PipelineSpec::gat4(),
            schedule: Arc::new(FillDrain),
            prep: PrepMode::Paper,
            prep_cache: Arc::new(MicrobatchCache::new()),
            seed: 0,
            eval_every: 10,
            balance: super::partition::CANONICAL_BALANCE.to_vec(),
            repartition_check: false,
            checkpoint_dir: None,
            checkpoint_every: 0,
            resume: false,
        }
    }

    /// The paper's "Chunk = 1*": full graph in the model, no re-build.
    pub fn full_graph_variant(mut self) -> Self {
        assert_eq!(self.chunks, 1, "1* variant requires chunks == 1");
        self.rebuild = false;
        self
    }

    /// `--repartition-check`: fold the run's measured stage means back
    /// into the partitioner and log (never switch) when the DP would
    /// now pick a different split. Best-effort — a failure here must
    /// not fail the training run.
    fn log_repartition_drift(&self, mc: &ModelConfig, stage_means: &[(f64, f64)]) {
        use super::partition::{drift_check, CostProfile};
        let template = CostProfile::closed_form(
            &self.dataset.profile,
            mc,
            &crate::simulator::DEVICES.v100,
            &CostProfile::default_calibration(),
        );
        match drift_check(&template, stage_means, &self.balance, self.chunks) {
            Ok(Some(part)) => eprintln!(
                "repartition-check: measured timings now favour balance \
                 {:?} (bottleneck {:.3e}s) over the running {:?}; NOT \
                 switching mid-run — rerun with `gnn-pipe partition` to \
                 adopt it",
                part.balance, part.bottleneck_s, self.balance
            ),
            Ok(None) => eprintln!(
                "repartition-check: measured timings confirm balance {:?}",
                self.balance
            ),
            Err(e) => eprintln!("repartition-check skipped: {e:#}"),
        }
    }

    pub fn train(&self, mc: &ModelConfig, epochs: usize) -> Result<PipelineResult> {
        let ds = self.dataset;
        let p = &ds.profile;
        let n = p.nodes;
        let train_mask = ds.splits.train_mask(n);
        anyhow::ensure!(self.replicas >= 1, "replicas must be >= 1");
        anyhow::ensure!(
            self.rebuild || self.replicas == 1,
            "the 1* variant bakes the full graph into the model and \
             cannot be replicated over partitions"
        );

        // Chunk plan is static across epochs (torchgpipe chunks by
        // index). Replication partitions the node set `replicas` times
        // finer: every replica owns `chunks` of the total chunks, and
        // the compiled artifact shapes follow the total count.
        let total_chunks = self.replicas * self.chunks;
        let plan = self.chunker.plan(&ds.graph, total_chunks);
        plan.check(n)?;
        let retention = retention_stats(&ds.graph, &plan);

        // Epoch-1 setup: compile all stage executables (paper's "setup"
        // epoch measured 7s on the DGX — ours is XLA CPU compile time).
        let setup = Timer::start();
        let mut pipe = PipelineEngine::new(
            self.engine,
            &p.name,
            &self.backend,
            total_chunks,
            self.spec.clone(),
            self.schedule.clone(),
        )?;
        pipe.device_resident = self.prep.device_resident();
        self.engine.warm_up(&pipe.artifact_names)?;

        // Induce every chunk sub-graph ONCE per plan: the lossy union
        // graph and the 1*/Cached micro-batch builds all reuse this
        // result. Paper-mode per-epoch rebuilds (and the Overlap
        // prefetcher) still re-induce — that IS the measured §7.2 cost.
        let induced = plan.induce_all(&ds.graph);
        let union = lossy_union_from_induced(n, &induced);

        // The 1* variant always skips the per-epoch re-build; Cached
        // mode builds once per key and reuses across runs.
        let static_mbs: Option<Arc<Vec<Microbatch>>> = if !self.rebuild {
            Some(Arc::new(microbatches_from_induced(
                ds,
                &induced,
                &self.backend,
                &train_mask,
            )?))
        } else if self.prep == PrepMode::Cached {
            Some(self.prep_cache.get_or_build(
                ds,
                &plan,
                &self.backend,
                &train_mask,
                Some(&induced),
            )?)
        } else {
            None
        };

        // Lossy-graph evaluator: the deterministic equivalent of a
        // forward through the chunked pipeline.
        let pipeline_evaluator =
            Evaluator::with_graph(self.engine, ds, &self.backend, &union)?;
        let full_evaluator = Evaluator::new(self.engine, ds, &self.backend)?;

        let order = self.engine.manifest.param_order.clone();
        let flat = flatten_params(&init_params(p, mc, self.seed), &order)?;
        let n_stages = self.spec.num_stages();

        // Stamp the recording with the run shape the trace analyzer
        // needs for its measured-vs-model drift table (a no-op unless
        // `--trace-out` started a recording).
        crate::trace::instant(
            "run_meta",
            &[
                ("kind", crate::trace::analyze::KIND_PIPELINE),
                ("stages", n_stages as i64),
                ("chunks", self.chunks as i64),
                (
                    "schedule",
                    crate::trace::analyze::schedule_id(self.schedule.name()),
                ),
                ("replicas", self.replicas as i64),
            ],
        );
        // Fresh epoch histogram per run: the CLI reads its percentile
        // print back from the registry.
        crate::metrics::registry::global().clear("pipeline_epoch_s");

        let group = ReplicaGroup::new(&pipe, self.replicas, self.replica_threads)?;
        let cx = EpochCtx {
            group: &group,
            evaluator: &pipeline_evaluator,
            order: &order,
            train_mask: &train_mask,
            setup_s: setup.secs(),
        };
        let mut st = TrainAccum {
            flat,
            adam: Adam::from_config(mc),
            timing: RunTiming { epochs, ..Default::default() },
            train_loss: Curve::default(),
            train_acc: Curve::default(),
            val_acc: Curve::default(),
            stage_fwd_sum: vec![0.0f64; n_stages],
            stage_bwd_sum: vec![0.0f64; n_stages],
            stage_calls: 0,
        };

        // Crash-safe checkpoint store: resume restores the exact
        // (params, Adam, curves, epoch) state, so the remaining epochs
        // replay bit-identically to the uninterrupted run.
        let label = format!(
            "pipeline:{}:{}:c{}:r{}",
            p.name, self.backend, self.chunks, self.replicas
        );
        let mut store = match &self.checkpoint_dir {
            Some(dir) => Some(Store::open(dir)?),
            None => {
                anyhow::ensure!(
                    !self.resume,
                    "--resume requires --checkpoint-dir"
                );
                None
            }
        };
        let mut start_epoch = 1usize;
        if self.resume {
            let s = store.as_ref().unwrap();
            for (seq, reason) in s.quarantined() {
                eprintln!(
                    "checkpoint store: quarantined corrupt v{seq}: {reason}"
                );
            }
            if let Some(v) = s.latest() {
                let ckpt = TrainCheckpoint::from_record(&s.load(v.seq)?)?;
                ckpt.check_resumable(&label, self.seed, epochs)?;
                vec_to_flat(&ckpt.flat, &mut st.flat)?;
                st.adam.import_state(ckpt.adam);
                st.train_loss = ckpt.train_loss;
                st.train_acc = ckpt.train_acc;
                st.val_acc = ckpt.val_acc;
                start_epoch = ckpt.epoch + 1;
                eprintln!(
                    "resumed {label} from checkpoint v{} (epoch {} of {epochs})",
                    v.seq, ckpt.epoch
                );
            } else {
                eprintln!(
                    "resume: no valid checkpoint in {}; starting fresh",
                    s.dir().display()
                );
            }
        }

        let transfer_base = pipe.transfer_seconds();
        match (&static_mbs, self.prep) {
            (Some(mbs), _) => {
                let mut feed = MbFeed::Static(mbs.as_slice());
                self.run_epochs(
                    start_epoch, epochs, &cx, &mut st, &mut feed,
                    &mut store, &label,
                )?;
            }
            (None, PrepMode::Overlap) => std::thread::scope(|scope| {
                // The prefetcher builds one set per REMAINING epoch —
                // a resumed run consumes exactly that many.
                let rx = spawn_prefetcher(
                    scope,
                    ds,
                    &plan,
                    &self.backend,
                    &train_mask,
                    (epochs + 1).saturating_sub(start_epoch),
                );
                let mut feed = MbFeed::Prefetch(rx);
                self.run_epochs(
                    start_epoch, epochs, &cx, &mut st, &mut feed,
                    &mut store, &label,
                )
            })?,
            (None, _) => {
                let mut feed = MbFeed::Rebuild {
                    pool: MicrobatchPool::new(),
                    ds,
                    plan: &plan,
                    backend: &self.backend,
                    train_mask: &train_mask,
                };
                self.run_epochs(
                    start_epoch, epochs, &cx, &mut st, &mut feed,
                    &mut store, &label,
                )?;
            }
        }
        st.timing.transfer_s = pipe.transfer_seconds() - transfer_base;
        // Release device-resident buffers: the prepared tensors stay
        // cached on the host (prep_cache), so a later run re-uploads
        // once instead of pinning device memory between runs.
        pipe.clear_static_buffers();

        let params = unflatten_params(st.flat, &order)?;
        let pipeline_eval = pipeline_evaluator.metrics(&params)?;
        let full_eval = full_evaluator.metrics(&params)?;
        let stage_means: Vec<(f64, f64)> = (0..n_stages)
            .map(|s| {
                (
                    st.stage_fwd_sum[s] / st.stage_calls.max(1) as f64,
                    st.stage_bwd_sum[s] / st.stage_calls.max(1) as f64,
                )
            })
            .collect();

        if self.repartition_check && self.balance.len() == n_stages {
            self.log_repartition_drift(mc, &stage_means);
        }

        Ok(PipelineResult {
            timing: st.timing,
            pipeline_eval,
            full_eval,
            train_loss: st.train_loss,
            train_acc: st.train_acc,
            val_acc: st.val_acc,
            retention,
            stage_means,
            params,
        })
    }

    /// Publish a checkpoint after `epoch` when one is due: every
    /// `checkpoint_every` epochs, plus always at the final epoch so a
    /// completed run leaves its end state durably versioned.
    fn maybe_checkpoint(
        &self,
        store: &mut Option<Store>,
        label: &str,
        st: &TrainAccum,
        epoch: usize,
        epochs: usize,
    ) -> Result<()> {
        let Some(store) = store.as_mut() else { return Ok(()) };
        let due = epoch == epochs
            || (self.checkpoint_every > 0 && epoch % self.checkpoint_every == 0);
        if !due {
            return Ok(());
        }
        let ckpt = TrainCheckpoint {
            label: label.to_string(),
            seed: self.seed,
            epoch,
            rng_state: Rng::new(self.seed).state(),
            flat: flat_to_vec(&st.flat)?,
            adam: st.adam.export_state(),
            train_loss: st.train_loss.clone(),
            train_acc: st.train_acc.clone(),
            val_acc: st.val_acc.clone(),
        };
        store.publish(&ckpt.to_record())?;
        Ok(())
    }

    /// The per-epoch loop, generic over where micro-batches come from.
    #[allow(clippy::too_many_arguments)]
    fn run_epochs(
        &self,
        start_epoch: usize,
        epochs: usize,
        cx: &EpochCtx,
        st: &mut TrainAccum,
        feed: &mut MbFeed,
        store: &mut Option<Store>,
        label: &str,
    ) -> Result<()> {
        // Owner for prefetched sets (delivered by value each epoch).
        let mut current: Vec<Microbatch> = Vec::new();
        for epoch in start_epoch..=epochs {
            let _epoch_span = crate::trace::span1("epoch", "epoch", epoch as i64);
            let t = Timer::start();

            // The paper re-built sub-graphs inside every forward pass;
            // Paper mode reproduces that cost per epoch on the critical
            // path, Overlap receives the set its prefetcher built during
            // the previous epoch (charging only the residual stall).
            let mbs: &[Microbatch] = match feed {
                MbFeed::Static(m) => *m,
                MbFeed::Rebuild { pool, ds, plan, backend, train_mask } => {
                    let _rebuild =
                        crate::trace::span1("rebuild", "epoch", epoch as i64);
                    let rt = Timer::start();
                    pool.rebuild(ds, plan, backend, train_mask)?;
                    st.timing.rebuild_s += rt.secs();
                    pool.microbatches()
                }
                MbFeed::Prefetch(rx) => {
                    let _wait_span =
                        crate::trace::span1("prefetch_wait", "epoch", epoch as i64);
                    let wait = Timer::start();
                    let (m, built_s) = rx.recv().map_err(|_| {
                        anyhow::anyhow!(
                            "micro-batch prefetcher exited before epoch {epoch}"
                        )
                    })??;
                    st.timing.rebuild_s += wait.secs();
                    st.timing.prep_overlap_s += built_s;
                    current = m;
                    &current
                }
            };

            let key = (self.seed as u32, epoch as u32);
            let out = {
                let _step =
                    crate::trace::span1("pipeline_step", "epoch", epoch as i64);
                cx.group.run_epoch(&st.flat, mbs, key)?
            };
            st.timing.allreduce_s += out.allreduce_s;
            st.timing.replica_cpu_s += out.replica_cpu_s;
            let loss = out.loss_sum / out.mask_count.max(1.0);
            anyhow::ensure!(loss.is_finite(), "loss diverged at epoch {epoch}");

            // Normalise sum-grads to mean-grads, then one Adam step.
            let _opt_span = crate::trace::span1("optimizer", "epoch", epoch as i64);
            let coord = Timer::start();
            let scale = 1.0 / out.mask_count.max(1.0) as f32;
            let grads: Vec<HostTensor> = out
                .grads
                .into_iter()
                .map(|mut g| {
                    for v in g.as_f32_mut().unwrap() {
                        *v *= scale;
                    }
                    g
                })
                .collect();
            st.adam.step(&mut st.flat, &grads)?;
            st.timing.coordinator_s += coord.secs();
            drop(_opt_span);

            // Stochastic training accuracy from the pipeline's own logits.
            st.train_acc
                .push(epoch, self.pipeline_train_acc(&out.logp, cx.train_mask));
            st.train_loss.push(epoch, loss);
            for (s, stage) in out.stage_timings.iter().enumerate() {
                st.stage_fwd_sum[s] += mean(&stage.fwd_s);
                st.stage_bwd_sum[s] += mean(&stage.bwd_s);
            }
            st.stage_calls += 1;

            let dt = if epoch == 1 { t.secs() + cx.setup_s } else { t.secs() };
            st.timing.per_epoch_s.push(dt);
            crate::metrics::registry::global().observe("pipeline_epoch_s", dt);
            if epoch == 1 {
                st.timing.epoch1_s = dt;
            } else {
                st.timing.epochs_rest_s += dt;
            }

            if self.eval_every > 0 && epoch % self.eval_every == 0 {
                let pm = unflatten_params(st.flat.clone(), cx.order)?;
                let m = cx.evaluator.metrics(&pm)?;
                st.val_acc.push(epoch, m.val_acc);
            }

            self.maybe_checkpoint(store, label, st, epoch, epochs)?;
        }
        Ok(())
    }

    /// Masked training accuracy over the pipeline's per-chunk log-probs.
    fn pipeline_train_acc(
        &self,
        logp: &[(Vec<u32>, Vec<f32>)],
        train_mask: &[f32],
    ) -> f64 {
        let c = self.dataset.profile.classes;
        let mut correct = 0.0;
        let mut total = 0.0;
        for (nodes, rows) in logp {
            for (i, &v) in nodes.iter().enumerate() {
                if train_mask[v as usize] <= 0.0 {
                    continue;
                }
                let row = &rows[i * c..(i + 1) * c];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j as i32)
                    .unwrap();
                total += 1.0;
                if pred == self.dataset.labels[v as usize] {
                    correct += 1.0;
                }
            }
        }
        if total == 0.0 {
            0.0
        } else {
            correct / total
        }
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}
