"""AOT path: lowering produces parseable HLO text, faithful manifests,
and stable positional signatures (the Rust runtime contract)."""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot, model as M, stages as S
from compile.configs import REPO_ROOT, load_datasets, load_model, load_pipeline
from tests.conftest import tiny_profile


def test_lower_one_writes_hlo_and_record(tmp_path):
    ds = tiny_profile()
    mc = load_model()
    fn = S.make_eval_fwd(ds, mc, "ell")
    specs = S.eval_fwd_specs(ds, mc, "ell")
    rec = aot.lower_one(
        "tiny_ell_eval_fwd", fn, specs, str(tmp_path),
        {"dataset": "tiny", "backend": "ell", "chunks": None, "kind": "eval_fwd"},
    )
    text = (tmp_path / "tiny_ell_eval_fwd.hlo.txt").read_text()
    assert text.startswith("HloModule")
    # ENTRY computation must carry one parameter per spec.
    assert text.count("parameter(") >= len(specs)
    assert [i["name"] for i in rec["inputs"]] == [n for n, _ in specs]
    assert rec["outputs"][0]["shape"] == [ds.nodes, ds.classes]
    assert rec["flops"] is None or rec["flops"] > 0


def test_keep_unused_preserves_signature(tmp_path):
    """s2_bwd famously loses its bias arg without keep_unused — the exact
    drift that broke the Rust pipeline once (see aot.py comment)."""
    ds = tiny_profile()
    mc = load_model()
    fns = S.stage_fns(ds, mc, "ell")
    specs = S.stage_specs(ds, mc, "ell", 1)["s2_bwd"]
    rec = aot.lower_one(
        "tiny_s2_bwd", fns["s2_bwd"], specs, str(tmp_path),
        {"dataset": "tiny", "backend": "ell", "chunks": 1, "kind": "s2_bwd"},
    )
    text = (tmp_path / "tiny_s2_bwd.hlo.txt").read_text()
    n_params = len({p for p in range(50) if f"parameter({p})" in text})
    assert n_params == len(specs), "unused args must stay in the signature"
    assert len(rec["inputs"]) == len(specs)


def test_real_manifest_consistency():
    """If artifacts/ has been built, cross-check it against the configs."""
    path = os.path.join(REPO_ROOT, "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    manifest = json.load(open(path))
    assert manifest["param_order"] == list(M.PARAM_NAMES)
    datasets = load_datasets()
    pc = load_pipeline()
    names = {a["name"] for a in manifest["artifacts"]}
    for ds in datasets:
        for be in M.BACKENDS:
            assert f"{ds}_{be}_train_step" in names
            assert f"{ds}_{be}_eval_fwd" in names
    for be in pc.pipeline_backends:
        for k in pc.chunks:
            for kind in ("s0_fwd", "s1_fwd", "s2_fwd", "s3_fwd",
                         "s3loss_bwd", "s2_bwd", "s1_bwd", "s0_bwd"):
                assert f"{pc.pipeline_dataset}_{be}_c{k}_{kind}" in names
    # every artifact file exists and content hash matches
    by_name = {a["name"]: a for a in manifest["artifacts"]}
    import hashlib

    for a in list(by_name.values())[:8]:
        p = os.path.join(REPO_ROOT, "artifacts", a["file"])
        text = open(p).read()
        assert hashlib.sha256(text.encode()).hexdigest() == a["hlo_sha256"]


def test_dataset_shape_arithmetic():
    """The padding arithmetic that Rust mirrors (config::tests does the
    same assertions on the Rust side)."""
    for ds in load_datasets().values():
        assert ds.e_cap % ds.edge_pad_multiple == 0
        assert ds.e_cap >= 2 * ds.undirected_edges + ds.nodes
        for k in (1, 2, 3, 4):
            assert ds.chunk_nodes(k) * k >= ds.nodes
            assert ds.chunk_e_cap(k) % ds.edge_pad_multiple == 0
        assert ds.chunk_nodes(1) == ds.nodes


def test_graph_arg_specs_dtypes():
    specs = M.graph_arg_specs("ell", 10, 64, 4)
    assert [s[0] for s in specs] == ["ell_idx", "ell_mask"]
    assert specs[0][2] == jnp.int32
    specs = M.graph_arg_specs("edgewise", 10, 64, 4)
    assert [s[0] for s in specs] == ["edge_src", "edge_dst", "edge_mask"]
    with pytest.raises(ValueError):
        M.graph_arg_specs("cuda", 1, 1, 1)
