//! Chrome trace-event / Perfetto export of a [`TraceData`]: the
//! `--trace-out trace.json` sink. Load the file at <https://ui.perfetto.dev>
//! or `chrome://tracing` — processes are replicas (`pid` = replica
//! index), threads are pipeline stages plus the reserved coordinator
//! and prep lanes (`tid`), named via `process_name`/`thread_name`
//! metadata events.
//!
//! The format is the JSON `traceEvents` array of the Trace Event
//! spec: `B`/`E` duration pairs and scoped `i` instants, `ts` in
//! microseconds (fractional), normalised so the first event is t=0.
//! Serialization goes through [`crate::util::json::Json`], the same
//! writer/parser the analyzer reads the file back with.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

use super::{tid_label, Event, EventKind, TraceData};

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<String, Json>>(),
    )
}

fn metadata(name: &str, pid: u32, tid: u32, label: String) -> Json {
    obj(vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", obj(vec![("name", Json::Str(label))])),
    ])
}

fn event(pid: u32, tid: u32, e: &Event, t0_ns: u64) -> Json {
    let ph = match e.kind {
        EventKind::Begin => "B",
        EventKind::End => "E",
        EventKind::Instant => "i",
    };
    let mut fields = vec![
        ("name", Json::Str(e.name.to_string())),
        ("ph", Json::Str(ph.to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num((e.ts_ns - t0_ns) as f64 / 1e3)),
    ];
    if e.kind == EventKind::Instant {
        // Thread-scoped instants: render as a marker on the track.
        fields.push(("s", Json::Str("t".to_string())));
    }
    if !e.args.is_empty() {
        let args = e
            .args
            .iter()
            .map(|&(k, v)| (k, Json::Num(v as f64)))
            .collect();
        fields.push(("args", obj(args)));
    }
    obj(fields)
}

/// Build the Chrome trace-event JSON document for a recording.
pub fn chrome_trace_json(data: &TraceData) -> Json {
    let t0_ns = data
        .tracks
        .iter()
        .flat_map(|t| t.events.iter().map(|e| e.ts_ns))
        .min()
        .unwrap_or(0);
    let mut events = Vec::with_capacity(data.total_events() + 2 * data.tracks.len());
    let mut named_pids = BTreeSet::new();
    for t in &data.tracks {
        if named_pids.insert(t.pid) {
            events.push(metadata(
                "process_name",
                t.pid,
                0,
                format!("replica {}", t.pid),
            ));
        }
        events.push(metadata("thread_name", t.pid, t.tid, tid_label(t.tid)));
    }
    for t in &data.tracks {
        for e in &t.events {
            events.push(event(t.pid, t.tid, e, t0_ns));
        }
    }
    Json::Obj(BTreeMap::from([
        ("displayTimeUnit".to_string(), Json::Str("ms".to_string())),
        ("traceEvents".to_string(), Json::Arr(events)),
    ]))
}

/// Write the recording as Chrome trace-event JSON (atomically — a
/// crash mid-write never leaves a truncated file).
pub fn write_chrome_trace(path: &Path, data: &TraceData) -> Result<()> {
    crate::util::fsio::atomic_write_str(path, &chrome_trace_json(data).to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Track, TID_COORD};

    fn sample() -> TraceData {
        let stage = Track {
            pid: 0,
            tid: 1,
            events: vec![
                Event {
                    name: "fwd",
                    kind: EventKind::Begin,
                    ts_ns: 2_000,
                    args: vec![("mb", 0)],
                },
                Event {
                    name: "fwd",
                    kind: EventKind::End,
                    ts_ns: 5_500,
                    args: Vec::new(),
                },
            ],
        };
        let coord = Track {
            pid: 0,
            tid: TID_COORD,
            events: vec![Event {
                name: "store_publish",
                kind: EventKind::Instant,
                ts_ns: 6_000,
                args: vec![("seq", 3)],
            }],
        };
        TraceData { tracks: vec![stage, coord] }
    }

    #[test]
    fn exports_metadata_events_and_normalised_timestamps() {
        let json = chrome_trace_json(&sample());
        let events = json.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process_name + 2 thread_name + 3 events.
        assert_eq!(events.len(), 6);
        let phs: Vec<&str> = events
            .iter()
            .map(|e| e.get("ph").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(phs, vec!["M", "M", "M", "B", "E", "i"]);
        // The earliest event lands at ts=0; the rest keep their offsets
        // in microseconds.
        let b = &events[3];
        assert_eq!(b.get("ts").unwrap().as_f64().unwrap(), 0.0);
        let e = &events[4];
        assert_eq!(e.get("ts").unwrap().as_f64().unwrap(), 3.5);
        // Args survive as numbers; instants are thread-scoped.
        assert_eq!(
            b.get("args").unwrap().get("mb").unwrap().as_f64().unwrap(),
            0.0
        );
        let i = &events[5];
        assert_eq!(i.get("s").unwrap().as_str().unwrap(), "t");
    }

    #[test]
    fn export_round_trips_through_the_json_parser() {
        let text = chrome_trace_json(&sample()).to_string();
        let parsed = Json::parse(&text).expect("exporter must emit valid JSON");
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        for ev in events {
            // Every event (metadata included) carries the core fields.
            assert!(ev.get("ph").is_some());
            assert!(ev.get("pid").is_some());
            assert!(ev.get("tid").is_some());
        }
    }

    #[test]
    fn empty_recording_is_still_a_valid_document() {
        let json = chrome_trace_json(&TraceData::default());
        let text = json.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert!(parsed.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    }
}
