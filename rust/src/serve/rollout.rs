//! Zero-downtime rollout planning: hot-swap, canary, and rollback for
//! versioned parameters.
//!
//! ## Batch-boundary-only swaps
//!
//! A served logit row depends only on `(params, node)`, and the serving
//! pipeline executes whole batches — so the *unit* of a version change
//! is the planned batch, never the individual request mid-batch. The
//! rollout planner ([`plan_rollout`]) takes each replica's deterministic
//! batch plan (its `close_s` timeline, a pure function of the trace
//! seed) and assigns every batch to exactly one of two store versions:
//!
//! * **hot-swap** — batches whose `close_s` is at or past
//!   [`RolloutPolicy::swap_at_s`] serve the candidate version: the swap
//!   lands on a batch boundary by construction, and a request is never
//!   split across versions;
//! * **canary** — before the swap point, a deterministic fraction
//!   [`RolloutPolicy::canary`] of batches serve the candidate, selected
//!   by hashing `(seed, replica, batch index)` — the same batches every
//!   replay, no RNG state to carry.
//!
//! ## The rollback gate
//!
//! [`RolloutGate`] prices the candidate cohort on the virtual timeline
//! the same way the admission layer does: a per-replica single-server
//! queue walk (`done = max(prev_done, close_s) + service_model_s`)
//! yields a modeled latency sample per candidate batch, and if the p99
//! of those samples exceeds the gate's target the whole rollout is
//! **rolled back** — every batch serves the base version, swap
//! included. Decisions are pure over `(batch plans, policy, service
//! model)`, so a rollback is bit-reproducible and the serving layer
//! can assert it planned the same fate on every replay.
//!
//! The execution layer ([`super::fleet::FleetSession::run_rollout`])
//! splits each replica's sub-trace into per-version cohorts from this
//! plan; because logits are `(params, node)`-pure, every request's row
//! is bit-identical to a pure run of whichever version served it
//! (`rust/tests/integration_store.rs` pins this).

use crate::util::hash::Fnv1a;

use super::latency::LatencySummary;

/// Candidate-cohort health gate: the modeled p99 the canary must stay
/// under, or the rollout rolls back.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RolloutGate {
    /// Modeled p99 target for candidate batches, seconds.
    pub p99_target_s: f64,
}

/// The rollout knobs (`gnn-pipe serve --canary P --swap-at T`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RolloutPolicy {
    /// Fraction of pre-swap batches routed to the candidate version
    /// (deterministic per `(seed, replica, batch)`); 0 disables the
    /// canary.
    pub canary: f64,
    /// Virtual time at which the fleet hot-swaps: batches closing at or
    /// after this instant serve the candidate. `None` = no swap.
    pub swap_at_s: Option<f64>,
    /// Seed for the canary hash — independent of the trace seed so the
    /// same trace can be canaried differently.
    pub seed: u64,
    /// `None` = no rollback gate (the rollout always goes through).
    pub gate: Option<RolloutGate>,
}

impl RolloutPolicy {
    /// No canary, no swap: everything serves the base version.
    pub fn none() -> RolloutPolicy {
        RolloutPolicy { canary: 0.0, swap_at_s: None, seed: 0, gate: None }
    }
}

/// The deterministic per-batch version assignment for one fleet run.
#[derive(Debug, Clone, PartialEq)]
pub struct RolloutPlan {
    /// `candidate[replica][batch]`: true when that batch serves the
    /// candidate version. All false after a rollback.
    pub candidate: Vec<Vec<bool>>,
    /// Pre-swap batches the canary hash routed to the candidate (as
    /// planned, counted even when the gate then rolled back).
    pub canary_batches: usize,
    /// Batches at/past the swap point (as planned).
    pub swapped_batches: usize,
    /// The gate tripped: every batch reverts to the base version.
    pub rolled_back: bool,
    /// The modeled candidate-cohort p99 the gate evaluated; `None`
    /// when no batch was planned onto the candidate.
    pub gate_p99_s: Option<f64>,
}

impl RolloutPlan {
    /// Batches assigned to the candidate in the *final* plan.
    pub fn candidate_batches(&self) -> usize {
        self.candidate
            .iter()
            .map(|r| r.iter().filter(|&&c| c).count())
            .sum()
    }
}

/// The deterministic canary coin: a uniform-ish fraction in `[0, 1)`
/// from `(seed, replica, batch)`. Pure — the same batch lands on the
/// same side of the threshold on every replay.
pub fn canary_fraction(seed: u64, replica: usize, batch: usize) -> f64 {
    let mut h = Fnv1a::new();
    h.write(b"canary");
    h.write_u64(seed);
    h.write_usize(replica);
    h.write_usize(batch);
    // Top 53 bits -> [0, 1) with full f64 mantissa resolution.
    (h.finish() >> 11) as f64 / 9_007_199_254_740_992.0
}

/// Assign every planned batch to a version, then gate the candidate
/// cohort. `batch_close_s[r]` is replica `r`'s batch-close timeline
/// (from [`super::batch::plan_batches`] over its sub-trace). Pure over
/// `(timelines, policy, service_model_s)`. Panics if `policy.canary`
/// is outside `[0, 1]`.
pub fn plan_rollout(
    batch_close_s: &[Vec<f64>],
    policy: &RolloutPolicy,
    service_model_s: f64,
) -> RolloutPlan {
    assert!(
        (0.0..=1.0).contains(&policy.canary),
        "canary fraction {} outside [0, 1]",
        policy.canary
    );
    let mut candidate: Vec<Vec<bool>> = Vec::with_capacity(batch_close_s.len());
    let (mut canary_batches, mut swapped_batches) = (0usize, 0usize);
    for (r, closes) in batch_close_s.iter().enumerate() {
        let mut flags = Vec::with_capacity(closes.len());
        for (b, &close_s) in closes.iter().enumerate() {
            let swapped =
                policy.swap_at_s.is_some_and(|t| close_s >= t);
            let canaried = !swapped
                && policy.canary > 0.0
                && canary_fraction(policy.seed, r, b) < policy.canary;
            if swapped {
                swapped_batches += 1;
            } else if canaried {
                canary_batches += 1;
            }
            flags.push(swapped || canaried);
        }
        candidate.push(flags);
    }

    // Gate: price the candidate cohort as a per-replica single-server
    // virtual queue (same modeling stance as the admission gate) and
    // take the p99 over all candidate batches' modeled latencies.
    let svc = service_model_s.max(0.0);
    let mut samples = Vec::new();
    for (r, closes) in batch_close_s.iter().enumerate() {
        let mut done = 0.0f64;
        for (b, &close_s) in closes.iter().enumerate() {
            if !candidate[r][b] {
                continue;
            }
            done = done.max(close_s) + svc;
            samples.push(done - close_s);
        }
    }
    let gate_p99_s = (!samples.is_empty())
        .then(|| LatencySummary::from_samples(&samples).p99_s);
    let rolled_back = match (&policy.gate, gate_p99_s) {
        (Some(g), Some(p99)) => p99 > g.p99_target_s,
        _ => false,
    };
    if rolled_back {
        for flags in &mut candidate {
            for f in flags.iter_mut() {
                *f = false;
            }
        }
    }
    RolloutPlan {
        candidate,
        canary_batches,
        swapped_batches,
        rolled_back,
        gate_p99_s,
    }
}

/// What `gnn-pipe serve --canary/--swap-at` prints about the rollout,
/// and what `bench serve-canary` snapshots.
#[derive(Debug, Clone, Default)]
pub struct RolloutReport {
    /// Store sequence numbers of the two versions.
    pub base_seq: u64,
    pub candidate_seq: u64,
    /// Served requests per version in the final plan.
    pub served_base: usize,
    pub served_candidate: usize,
    pub canary_batches: usize,
    pub swapped_batches: usize,
    pub rolled_back: bool,
    pub gate_p99_s: Option<f64>,
}

impl RolloutReport {
    pub fn render(&self) -> String {
        let gate = match self.gate_p99_s {
            Some(p) => format!("{:.1} ms", p * 1e3),
            None => "-".to_string(),
        };
        format!(
            "rollout: base v{} served {} / candidate v{} served {} \
             ({} canary batches, {} swapped, gate p99 {gate}{})",
            self.base_seq,
            self.served_base,
            self.candidate_seq,
            self.served_candidate,
            self.canary_batches,
            self.swapped_batches,
            if self.rolled_back { ", ROLLED BACK" } else { "" },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn timelines() -> Vec<Vec<f64>> {
        // Two replicas, batches closing every 10 ms.
        (0..2)
            .map(|r| {
                (0..200)
                    .map(|b| 0.010 * (b as f64 + 1.0) + r as f64 * 1e-4)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn canary_fraction_is_deterministic_and_in_range() {
        let mut sum = 0.0;
        for b in 0..4096 {
            let f = canary_fraction(7, 1, b);
            assert!((0.0..1.0).contains(&f));
            assert_eq!(f, canary_fraction(7, 1, b));
            sum += f;
        }
        let mean = sum / 4096.0;
        assert!((0.4..0.6).contains(&mean), "mean {mean} far from uniform");
        // Different seeds decorrelate the coin.
        assert_ne!(canary_fraction(7, 1, 3), canary_fraction(8, 1, 3));
    }

    #[test]
    fn no_canary_no_swap_serves_everything_on_base() {
        let plan = plan_rollout(&timelines(), &RolloutPolicy::none(), 0.01);
        assert_eq!(plan.candidate_batches(), 0);
        assert_eq!(plan.canary_batches, 0);
        assert_eq!(plan.swapped_batches, 0);
        assert!(!plan.rolled_back);
        assert_eq!(plan.gate_p99_s, None);
    }

    #[test]
    fn full_canary_serves_everything_on_candidate() {
        let policy = RolloutPolicy {
            canary: 1.0,
            swap_at_s: None,
            seed: 3,
            gate: None,
        };
        let plan = plan_rollout(&timelines(), &policy, 0.001);
        assert_eq!(plan.candidate_batches(), 400);
        assert_eq!(plan.canary_batches, 400);
    }

    #[test]
    fn canary_share_tracks_the_requested_fraction() {
        let policy = RolloutPolicy {
            canary: 0.3,
            swap_at_s: None,
            seed: 11,
            gate: None,
        };
        let plan = plan_rollout(&timelines(), &policy, 0.001);
        let share = plan.candidate_batches() as f64 / 400.0;
        assert!((0.2..0.4).contains(&share), "share {share}");
        // Deterministic: the same batches every replay.
        assert_eq!(plan, plan_rollout(&timelines(), &policy, 0.001));
    }

    #[test]
    fn swap_assigns_exactly_the_suffix_at_a_batch_boundary() {
        let policy = RolloutPolicy {
            canary: 0.0,
            swap_at_s: Some(1.0),
            seed: 0,
            gate: None,
        };
        let plan = plan_rollout(&timelines(), &policy, 0.001);
        for (r, closes) in timelines().iter().enumerate() {
            for (b, &close_s) in closes.iter().enumerate() {
                assert_eq!(
                    plan.candidate[r][b],
                    close_s >= 1.0,
                    "replica {r} batch {b}"
                );
            }
        }
        assert!(plan.swapped_batches > 0);
        assert_eq!(plan.canary_batches, 0);
    }

    #[test]
    fn gate_trips_and_rolls_back_to_all_base() {
        // Service model far slower than the batch cadence: the virtual
        // candidate queue diverges and the modeled p99 blows up.
        let hot = RolloutPolicy {
            canary: 1.0,
            swap_at_s: None,
            seed: 5,
            gate: Some(RolloutGate { p99_target_s: 0.05 }),
        };
        let plan = plan_rollout(&timelines(), &hot, 0.100);
        assert!(plan.rolled_back);
        assert_eq!(plan.candidate_batches(), 0, "rollback reverts every batch");
        assert!(plan.gate_p99_s.unwrap() > 0.05);
        // The planned counts survive the rollback for reporting.
        assert_eq!(plan.canary_batches, 400);
        // A feasible target keeps the rollout.
        let ok = RolloutPolicy {
            gate: Some(RolloutGate { p99_target_s: 10.0 }),
            ..hot
        };
        let plan = plan_rollout(&timelines(), &ok, 0.001);
        assert!(!plan.rolled_back);
        assert_eq!(plan.candidate_batches(), 400);
    }

    #[test]
    fn gate_without_candidates_never_trips() {
        let policy = RolloutPolicy {
            canary: 0.0,
            swap_at_s: None,
            seed: 0,
            gate: Some(RolloutGate { p99_target_s: 1e-9 }),
        };
        let plan = plan_rollout(&timelines(), &policy, 0.1);
        assert!(!plan.rolled_back);
        assert_eq!(plan.gate_p99_s, None);
    }

    #[test]
    #[should_panic(expected = "canary fraction")]
    fn out_of_range_canary_panics() {
        let policy = RolloutPolicy {
            canary: 1.5,
            swap_at_s: None,
            seed: 0,
            gate: None,
        };
        plan_rollout(&timelines(), &policy, 0.01);
    }
}
