//! E10 — hybrid data×pipe parallelism: replicated pipelines over graph
//! partitions (`--replicas R`) against the pipe-only baseline on the
//! *same total data*.
//!
//! All rows share one fixed total partition (R × chunks/replica =
//! `total`), so every configuration trains the identical micro-batch
//! set and the identical per-micro-batch forwards — the rows differ
//! only in how gradients are summed (the deterministic tree all-reduce
//! association) and in how the work maps onto devices. The `dLoss vs
//! R=1` column is therefore expected to sit at float-rounding scale.
//!
//! Each row prints the real CPU run next to two DGX projections: the
//! pipe-only baseline (`Scenarios::hybrid_epoch` at R=1 on the same
//! total partition) and the row's own hybrid layout (R nodes × S V100s
//! with the gradient tree on the modeled inter-node link).

use anyhow::Result;

use crate::metrics::Table;
use crate::pipeline::PipelineSpec;
use crate::simulator::Scenarios;

use super::{framework_label, schedule_label, BenchCtx};

pub fn bench_hybrid(ctx: &BenchCtx) -> Result<String> {
    let backend = "ell";
    let total = ctx
        .cfg
        .pipeline
        .chunks
        .iter()
        .copied()
        .max()
        .unwrap_or(4)
        .max(2);
    // Every (R, chunks/replica) factorisation of the same total
    // partition: for total = 4 that is (1,4), (2,2), (4,1).
    let configs: Vec<(usize, usize)> = (1..=total)
        .filter(|r| total % r == 0)
        .map(|r| (r, total / r))
        .collect();

    let spec = PipelineSpec::gat4();
    let baseline = ctx.pipeline_run_replicas(backend, total, false, false, ctx.prep, 1)?;
    let single = ctx.single_run("pubmed", backend)?;
    let scen = Scenarios::calibrate_from_cpu(
        &ctx.engine.manifest,
        &format!("pubmed_{backend}_train_step"),
        single.timing.avg_epoch_s(),
    )?;
    let pipe_only = scen.hybrid_epoch(
        &spec,
        "pubmed",
        backend,
        1,
        total,
        true,
        baseline.host_rebuild_per_chunk_s,
        ctx.schedule.as_ref(),
        ctx.prep,
    )?;

    let mut table = Table::new(&[
        "Replicas",
        "Chunks/rep",
        "Ave. epoch (s)",
        "allreduce_s (host)",
        "Final loss",
        "dLoss vs R=1",
        "Test acc (full)",
        "DGX pipe-only (s, sim)",
        "DGX hybrid (s, sim)",
        "sim allreduce_s",
    ]);
    let mut csv = String::from(
        "replicas,chunks_per_replica,avg_epoch_s,allreduce_s,final_loss,dloss_vs_r1,\
         test_acc_full,dgx_pipe_only_s,dgx_hybrid_s,dgx_allreduce_s\n",
    );

    for &(r, chunks) in &configs {
        let run = ctx.pipeline_run_replicas(backend, chunks, false, false, ctx.prep, r)?;
        let dloss = run.pipeline_eval.train_loss - baseline.pipeline_eval.train_loss;
        let hybrid = scen.hybrid_epoch(
            &spec,
            "pubmed",
            backend,
            r,
            chunks,
            true,
            run.host_rebuild_per_chunk_s,
            ctx.schedule.as_ref(),
            ctx.prep,
        )?;
        table.row(&[
            format!("{r}"),
            format!("{chunks}"),
            format!("{:.4}", run.timing.avg_epoch_s()),
            format!("{:.5}", run.timing.allreduce_s),
            format!("{:.4}", run.pipeline_eval.train_loss),
            format!("{dloss:+.2e}"),
            format!("{:.4}", run.full_eval.test_acc),
            format!("{:.5}", pipe_only.epoch_s),
            format!("{:.5}", hybrid.epoch_s),
            format!("{:.2e}", hybrid.allreduce_s),
        ]);
        csv.push_str(&format!(
            "{r},{chunks},{:.5},{:.6},{:.6},{dloss:.6e},{:.4},{:.6},{:.6},{:.6e}\n",
            run.timing.avg_epoch_s(),
            run.timing.allreduce_s,
            run.pipeline_eval.train_loss,
            run.full_eval.test_acc,
            pipe_only.epoch_s,
            hybrid.epoch_s,
            hybrid.allreduce_s,
        ));
    }

    ctx.write_csv("hybrid.csv", &csv)?;
    Ok(format!(
        "Hybrid data×pipe — {} {} total-partition={total} {} prep={} ({} epochs)\n{}\n\
         shape check: every row trains the same {total}-way partition, so dLoss \
         stays at float-rounding scale (the deterministic tree all-reduce only \
         changes summation association); the hybrid DGX column trades a shorter \
         per-replica drain against ceil(log2 R) gradient-reduction rounds on \
         the inter-node link\n",
        framework_label(backend),
        ctx.cfg.pipeline.pipeline_dataset,
        schedule_label(ctx.schedule.name()),
        ctx.prep.name(),
        ctx.epochs,
        table.render()
    ))
}
