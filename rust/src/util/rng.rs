//! Deterministic, dependency-free RNG: splitmix64 core with helpers.
//!
//! Every stochastic choice in the coordinator (dataset synthesis,
//! parameter init, chunk shuffling) flows through this module with an
//! explicit seed, so whole experiments are reproducible from the config
//! seeds alone. Model-side randomness (dropout) is separate: it lives in
//! the HLO and is driven by the uint32[2] key argument.

/// splitmix64: tiny, well-mixed, passes BigCrush as a 64-bit generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    /// Derive an independent stream (used per-tensor / per-subsystem).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    /// The stream cursor: everything this generator will ever emit is a
    /// pure function of this value. Persist it in a checkpoint and
    /// restore with [`Rng::from_state`] to resume the stream exactly
    /// where it left off.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator at a saved cursor. Note this is NOT
    /// `Rng::new`: the seed-mixing constant was already folded in when
    /// the cursor was captured, so the state is restored verbatim.
    pub fn from_state(state: u64) -> Rng {
        Rng { state }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free Lemire-style bounded sampling.
        let n = n as u64;
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-300 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), order random.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Partial Fisher-Yates over an index map — O(k) memory for small k
        // would need a hashmap; n here is at most node count, so a full
        // vec is fine and branch-free.
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample from a discrete distribution given cumulative weights.
    pub fn categorical(&mut self, cumulative: &[f64]) -> usize {
        let total = *cumulative.last().expect("empty categorical");
        let x = self.next_f64() * total;
        match cumulative.binary_search_by(|c| c.partial_cmp(&x).unwrap()) {
            Ok(i) => (i + 1).min(cumulative.len() - 1),
            Err(i) => i.min(cumulative.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_round_trip_resumes_the_stream() {
        let mut a = Rng::new(42);
        for _ in 0..17 {
            a.next_u64();
        }
        let cursor = a.state();
        let mut b = Rng::from_state(cursor);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Rng::new(7);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(11);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let (mut s, mut s2) = (0.0, 0.0);
        let n = 20_000;
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(13);
        let s = r.sample_distinct(100, 40);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 40);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        let cum = [0.1, 0.1, 1.0]; // class 1 has zero mass
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.categorical(&cum)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }
}
