//! The serving session: trace in, latency-annotated logits out.
//!
//! ## Execution model
//!
//! Node classification on a *static* graph is served at full-graph
//! shape: the one `chunks = 1` micro-batch (built through the shared
//! [`MicrobatchCache`], induced once per plan like the training path —
//! and lossless, because a single sequential chunk cuts no edges) stays
//! resident on the device, and every dispatched batch drives one
//! deterministic staged forward over it through the forward-only
//! pipeline ([`PipelineSpec::gat4_serve`] + `ServeStream`). Batches
//! stream: while batch `b` runs its GAT2 stage, batch `b+1` is already
//! in GAT1 — under sustained load all stages stay busy and the
//! fill/drain bubble is a one-off, which is exactly the serving claim
//! of the paper's GPipe analysis. Memory stays bounded however long
//! the trace is: the forward stage links are bounded channels (a fast
//! stage 0 blocks instead of piling activations ahead of the
//! bottleneck stage — see `pipeline::engine`'s `LinkTx`), and the
//! final stage hands each batch's log-probs to a sink that keeps only
//! the requested rows.
//!
//! Because the chunk is lossless and the stage cut is the trained
//! model's, a served logit row is the *same* computation `full_eval`
//! performs — serve-vs-`full_eval` parity and replay bit-identity are
//! pinned by `rust/tests/integration_serve.rs`.
//!
//! ## What is measured vs modeled
//!
//! Queueing (batch-formation) delay lives on the trace's **virtual**
//! timeline — a pure function of `(seed, rate, policy)`, reproducible
//! bit for bit. Execution spans (pipeline residence, row gather) are
//! **measured** on the replay. The two are reported as separate spans
//! and summed into the per-request total, and the closed-form
//! counterpart (`Scenarios::serve_latency`) prices the same
//! decomposition so `bench serve` can put them side by side.
//!
//! [`MicrobatchCache`]: crate::pipeline::MicrobatchCache
//! [`PipelineSpec::gat4_serve`]: crate::pipeline::PipelineSpec::gat4_serve

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::batching::{Chunker, SequentialChunker};
use crate::data::Dataset;
use crate::faults::StageFaults;
use crate::metrics::Timer;
use crate::pipeline::{
    MicrobatchCache, PipelineEngine, PipelineSpec, ServeStream,
};
use crate::runtime::{Engine, HostTensor};

use super::batch::{plan_batches, BatchPolicy};
use super::latency::{LatencySummary, RequestLatency, ServeReport};
use super::trace::Request;

/// One completed batch, as recorded by the final-stage sink.
struct BatchRecord {
    batch: usize,
    /// Seconds from just before the pipeline pass started until the
    /// final stage finished this batch's forward (stamped in the sink,
    /// before the gather).
    done_s: f64,
    /// Seconds spent gathering the requested rows out of the output.
    gather_s: f64,
    /// Gathered log-prob rows, one per member request, in member order.
    rows: Vec<Vec<f32>>,
}

/// Everything a serve run produces: the aggregate report plus the
/// per-request payloads the parity/determinism tests inspect.
#[derive(Debug)]
pub struct ServeOutput {
    pub report: ServeReport,
    /// Served log-prob row per request, indexed like the trace.
    pub request_logits: Vec<Vec<f32>>,
    /// Per-request span decomposition, indexed like the trace (the
    /// fleet session re-aggregates these across replicas).
    pub latencies: Vec<RequestLatency>,
    /// Request indices in completion order (batch dispatch order, then
    /// member order) — the latency event ordering. Structurally this is
    /// the flattened batch plan (the session's FIFO ensure pins it);
    /// it is exposed so consumers need not recompute the plan, and the
    /// determinism test checks it against an independently recomputed
    /// plan.
    pub completion_order: Vec<usize>,
}

/// Default stage-link watchdog for serving pipelines: per-stage work is
/// milliseconds, so a multi-second silent link means the upstream stage
/// stalled — fail with a diagnosable `StageTimeout` instead of hanging
/// the replica forever. Generous enough for slow CI machines.
pub const DEFAULT_WATCHDOG_S: f64 = 10.0;

/// Reject nonsensical watchdog settings with a configuration error at
/// parse time. Zero is the dangerous one: every stage-link recv would
/// time out instantly, so the fleet would spin `StageTimeout`s instead
/// of serving — a config mistake, not a chaos experiment, and it must
/// say so. Shared by `gnn-pipe serve` and anything else that accepts
/// `--watchdog-s`.
pub fn validate_watchdog_s(watchdog_s: f64) -> anyhow::Result<()> {
    anyhow::ensure!(
        watchdog_s.is_finite() && watchdog_s > 0.0,
        "--watchdog-s must be a positive number of seconds (got \
         {watchdog_s}); 0 would time out every stage link instantly"
    );
    Ok(())
}

/// A bound serving session: dataset + backend + the shared prep cache.
pub struct ServeSession<'e> {
    engine: &'e Engine,
    ds: &'e Dataset,
    backend: String,
    /// Shared with training so a bench session builds the full-graph
    /// micro-batch once across serve and train runs on one plan.
    pub prep_cache: Arc<MicrobatchCache>,
    /// Stage-link watchdog threaded into every pipeline this session
    /// builds ([`DEFAULT_WATCHDOG_S`]; tests shrink it to keep stall
    /// scenarios fast). Also the threshold deciding whether an injected
    /// `StageStall` dooms its replica at plan time — see
    /// `serve::fleet::plan_fleet_faults`.
    pub watchdog_s: f64,
}

impl<'e> ServeSession<'e> {
    /// A serving session over one engine/dataset/backend triple.
    pub fn new(engine: &'e Engine, ds: &'e Dataset, backend: &str) -> ServeSession<'e> {
        ServeSession {
            engine,
            ds,
            backend: backend.to_string(),
            prep_cache: Arc::new(MicrobatchCache::new()),
            watchdog_s: DEFAULT_WATCHDOG_S,
        }
    }

    /// Whether the serving artifacts exist in `engine`'s manifest —
    /// artifact dirs built before the serving subsystem lack the
    /// `s*_eval_fwd` programs. The one probe the serve tests/benches
    /// share, derived from the serve spec's own artifact kinds and the
    /// `{dataset}_{backend}_c{chunks}_{kind}` convention
    /// `PipelineEngine` resolves.
    pub fn artifacts_available(engine: &Engine, dataset: &str, backend: &str) -> bool {
        PipelineSpec::gat4_serve()
            .artifact_kinds()
            .iter()
            .all(|kind| engine.manifest.has(&format!("{dataset}_{backend}_c1_{kind}")))
    }

    /// Replay `trace` under `policy` with the given flat parameters
    /// (manifest order — the same vector training produces).
    pub fn run(
        &self,
        params: &[HostTensor],
        trace: &[Request],
        policy: &BatchPolicy,
    ) -> Result<ServeOutput> {
        self.run_faulted(params, trace, policy, None)
    }

    /// [`run`] with an injected execution-fault table (see
    /// [`crate::faults`]): stage workers consult `faults` before every
    /// forward batch. Faults perturb *timing and errors only* — when a
    /// faulted run completes, its logits are bit-identical to the
    /// fault-free run, because a served row depends only on
    /// `(params, node)`.
    ///
    /// [`run`]: ServeSession::run
    pub fn run_faulted(
        &self,
        params: &[HostTensor],
        trace: &[Request],
        policy: &BatchPolicy,
        faults: Option<Arc<StageFaults>>,
    ) -> Result<ServeOutput> {
        self.run_versioned(params, trace, policy, faults, None)
    }

    /// [`run_faulted`] serving one *store version* of the parameters:
    /// `param_version` keys the device-resident parameter buffers on
    /// [`crate::store::Version::content_hash`], so replaying against a
    /// version the pipeline already uploaded is a static-cache hit and
    /// a hot-swap re-uploads exactly once. Logits depend only on
    /// `(params, node)`, so a versioned run is bit-identical to the
    /// unversioned run with the same parameter values — the rollout
    /// layer (`serve::rollout`) exploits this to split a trace into
    /// per-version cohorts without perturbing any served row.
    ///
    /// [`run_faulted`]: ServeSession::run_faulted
    pub fn run_versioned(
        &self,
        params: &[HostTensor],
        trace: &[Request],
        policy: &BatchPolicy,
        faults: Option<Arc<StageFaults>>,
        param_version: Option<u64>,
    ) -> Result<ServeOutput> {
        anyhow::ensure!(!trace.is_empty(), "cannot serve an empty trace");
        let n = self.ds.profile.nodes;
        for (i, r) in trace.iter().enumerate() {
            anyhow::ensure!(
                (r.node as usize) < n,
                "request {i} queries node {} outside 0..{n}",
                r.node
            );
        }

        // One-off setup: the lossless full-graph micro-batch (cached
        // across runs) and the forward-only stage executables.
        let setup = Timer::start();
        let plan = SequentialChunker.plan(&self.ds.graph, 1);
        plan.check(n)?;
        let train_mask = self.ds.splits.train_mask(n);
        let mbs = self.prep_cache.get_or_build(
            self.ds,
            &plan,
            &self.backend,
            &train_mask,
            None,
        )?;
        let mb = &mbs[0];
        // A single sequential chunk maps node id == row id; the row
        // gather below relies on it.
        anyhow::ensure!(
            mb.nodes.iter().enumerate().all(|(i, &v)| i as u32 == v),
            "single-chunk plan must be the identity node order"
        );
        let mut pipe = PipelineEngine::new_forward_only(
            self.engine,
            &self.ds.profile.name,
            &self.backend,
            1,
            PipelineSpec::gat4_serve(),
            Arc::new(ServeStream),
        )
        .context("building the forward-only serve pipeline (older \
                  artifact dirs lack the s*_eval_fwd artifacts; re-run \
                  `make artifacts`)")?;
        pipe.device_resident = true;
        pipe.watchdog_s = Some(self.watchdog_s.max(1e-3));
        pipe.faults = faults;
        pipe.param_version = param_version;
        self.engine.warm_up(&pipe.artifact_names)?;
        let setup_s = setup.secs();

        // Deterministic batch plan from the virtual timeline, and the
        // per-batch query-node lists (the measured host "prep" work).
        let batches = plan_batches(trace, policy);
        let prep_t = Timer::start();
        let batch_nodes: Vec<Vec<u32>> = batches
            .iter()
            .map(|b| b.requests.iter().map(|&i| trace[i].node).collect())
            .collect();
        let prep_total_s = prep_t.secs();

        // The streaming pass: the sink runs on the final stage's worker
        // thread, gathering each batch's requested rows the moment its
        // forward completes.
        let classes = self.ds.profile.classes;
        let records: Mutex<Vec<BatchRecord>> =
            Mutex::new(Vec::with_capacity(batches.len()));
        let static_hits_before = pipe.static_hits();
        let t0 = Instant::now();
        let sink = |m: usize, out: HostTensor| -> Result<()> {
            let done_s = t0.elapsed().as_secs_f64();
            let g = Instant::now();
            let logp = out.as_f32()?;
            let rows: Vec<Vec<f32>> = batch_nodes[m]
                .iter()
                .map(|&v| {
                    let r = v as usize * classes;
                    logp[r..r + classes].to_vec()
                })
                .collect();
            let gather_s = g.elapsed().as_secs_f64();
            records
                .lock()
                .unwrap()
                .push(BatchRecord { batch: m, done_s, gather_s, rows });
            Ok(())
        };
        let out = pipe.run_forward(params, mb, batches.len(), &sink)?;
        let static_hits = pipe.static_hits() - static_hits_before;
        // Host-cached tensors rebuild the device copies cheaply on the
        // next run; don't pin device memory between runs.
        pipe.clear_static_buffers();

        let records = records.into_inner().unwrap();
        anyhow::ensure!(
            records.len() == batches.len(),
            "sink saw {} of {} batches",
            records.len(),
            batches.len()
        );
        // The BatchSink contract (single final-stage producer, FIFO
        // serve schedule) delivers records strictly in batch order —
        // pin that instead of maintaining machinery for an ordering
        // that cannot occur.
        for (i, r) in records.iter().enumerate() {
            anyhow::ensure!(
                r.batch == i,
                "sink delivered batch {} at position {i} (FIFO contract broken)",
                r.batch
            );
        }

        // Batch injection offsets: stage 0's executable seconds are
        // back-to-back, so Σ fwd0[0..b] is when batch b *could* enter
        // the pipeline if nothing downstream pushed back. Residence(b)
        // = completion(b) - that offset, which therefore folds in any
        // time stage 0 spent blocked on the bounded forward links —
        // i.e. measured `execute` includes queueing behind the
        // bottleneck stage, the quantity the model's M/D/1 term prices.
        // Batch 0's span additionally absorbs the worker spawn overhead
        // (the pipeline fill the serving regime amortises).
        let fwd0 = &out.stage_timings[0].fwd_s;
        anyhow::ensure!(fwd0.len() == batches.len(), "stage-0 timing arity");
        let mut inject_s = vec![0.0f64; batches.len()];
        for b in 1..batches.len() {
            inject_s[b] = inject_s[b - 1] + fwd0[b - 1];
        }

        let prep_each_s = prep_total_s / trace.len() as f64;
        let mut latencies = vec![RequestLatency::default(); trace.len()];
        let mut request_logits: Vec<Vec<f32>> = vec![Vec::new(); trace.len()];
        let mut completion_order = Vec::with_capacity(trace.len());
        for (b, rec) in records.into_iter().enumerate() {
            let execute_s = (rec.done_s - inject_s[b]).max(0.0);
            let download_s = rec.gather_s;
            // Move the gathered rows into place — they were allocated
            // once in the sink and are dead here otherwise.
            for (&req, row) in batches[b].requests.iter().zip(rec.rows) {
                completion_order.push(req);
                request_logits[req] = row;
                latencies[req] = RequestLatency {
                    queue_s: batches[b].close_s - trace[req].arrival_s,
                    prep_s: prep_each_s,
                    execute_s,
                    download_s,
                };
            }
        }

        let collect = |f: fn(&RequestLatency) -> f64| -> Vec<f64> {
            latencies.iter().map(f).collect()
        };
        let totals: Vec<f64> = latencies.iter().map(|l| l.total_s()).collect();
        let trace_span_s = trace.last().unwrap().arrival_s.max(1e-12);
        let report = ServeReport {
            backend: self.backend.clone(),
            requests: trace.len(),
            batches: batches.len(),
            mean_batch: trace.len() as f64 / batches.len() as f64,
            max_batch_observed: batches.iter().map(|b| b.len()).max().unwrap_or(0),
            offered_rps: trace.len() as f64 / trace_span_s,
            throughput_rps: trace.len() as f64 / out.wall_s.max(1e-12),
            wall_s: out.wall_s,
            setup_s,
            prep_total_s,
            static_hits,
            queue: LatencySummary::from_samples(&collect(|l| l.queue_s)),
            prep: LatencySummary::from_samples(&collect(|l| l.prep_s)),
            execute: LatencySummary::from_samples(&collect(|l| l.execute_s)),
            download: LatencySummary::from_samples(&collect(|l| l.download_s)),
            total: LatencySummary::from_samples(&totals),
            stage_fwd_means_s: out
                .stage_timings
                .iter()
                .map(|st| {
                    if st.fwd_s.is_empty() {
                        0.0
                    } else {
                        st.fwd_s.iter().sum::<f64>() / st.fwd_s.len() as f64
                    }
                })
                .collect(),
        };
        Ok(ServeOutput { report, request_logits, latencies, completion_order })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watchdog_validation_rejects_zero_and_nonsense() {
        // 0 is the dangerous misconfiguration: every stage-link recv
        // would time out instantly, spinning StageTimeouts instead of
        // serving. It must be a clear config error at parse time.
        let err = validate_watchdog_s(0.0).unwrap_err().to_string();
        assert!(err.contains("--watchdog-s"), "names the flag: {err}");
        assert!(err.contains("positive"), "says what's wrong: {err}");
        assert!(
            err.contains("instantly"),
            "explains the failure mode zero would cause: {err}"
        );
        assert!(validate_watchdog_s(-1.0).is_err());
        assert!(validate_watchdog_s(f64::NAN).is_err());
        assert!(validate_watchdog_s(f64::INFINITY).is_err());
        // Any positive finite value is fine, including sub-second test
        // watchdogs and the serving default.
        validate_watchdog_s(0.05).unwrap();
        validate_watchdog_s(DEFAULT_WATCHDOG_S).unwrap();
    }
}
