//! Prep-path micro-benchmarks (Criterion-style statistics, no external
//! harness offline): the §7.2 host hot spots — `induce_subgraph`,
//! `EllGraph::from_graph`, `CooGraph::from_graph`,
//! `prepare_microbatches` (serial / parallel / pooled / cached) — with
//! mean ± stddev per iteration, dumped to `BENCH_prep.json` at the repo
//! root so future PRs have a perf trajectory to compare against.
//!
//! Run: `cargo bench --bench prep` (compile-checked in CI with
//! `cargo bench --no-run`). `cargo bench --bench prep -- --quick` cuts
//! iteration counts ~10x — the fast path CI's `bench-trajectory` job
//! runs per PR to keep the perf trajectory accumulating.

mod bench_util;

use bench_util::{bench, quick_mode, scaled, write_snapshot};

use gnn_pipe::batching::{Chunker, SequentialChunker};
use gnn_pipe::config::Config;
use gnn_pipe::data::generate;
use gnn_pipe::graph::{induce_subgraph, CooGraph, EllGraph, Graph};
use gnn_pipe::pipeline::{
    lossy_union_from_induced, prepare_microbatches,
    prepare_microbatches_parallel, MicrobatchCache, MicrobatchPool,
};

/// The pre-PR-4 induction: materialise a `(u32, u32)` edge list, then
/// pay `Graph::from_undirected_edges`'s per-row sort + duplicate
/// re-validation. Kept here as the baseline the CSR-native fast path
/// (`induce_subgraph` emitting rows directly) is measured against.
fn induce_via_edge_list(g: &Graph, nodes: &[u32]) -> Graph {
    let mut remap = vec![u32::MAX; g.num_nodes()];
    for (new, &old) in nodes.iter().enumerate() {
        remap[old as usize] = new as u32;
    }
    let mut edges = Vec::new();
    for (new_a, &old_a) in nodes.iter().enumerate() {
        for &old_b in g.neighbors(old_a as usize) {
            let new_b = remap[old_b as usize];
            if new_b != u32::MAX && (new_a as u32) < new_b {
                edges.push((new_a as u32, new_b));
            }
        }
    }
    Graph::from_undirected_edges(nodes.len(), &edges).unwrap()
}

fn main() {
    let quick = quick_mode();
    let iters = |n: usize| scaled(quick, n);
    let cfg = Config::load().expect("configs");
    let profile = cfg.dataset("pubmed").unwrap().clone();
    let ds = generate(&profile).unwrap();
    let g = &ds.graph;
    let chunks = 4usize;
    let plan = SequentialChunker.plan(g, chunks);
    let train_mask = ds.splits.train_mask(profile.nodes);
    let sub = induce_subgraph(g, &plan.chunks[0]);
    let e_cap = profile.chunk_e_cap(chunks);
    println!(
        "== prep microbench (pubmed-profile graph: {} nodes, {} edges, {chunks} chunks{}) ==",
        g.num_nodes(),
        g.num_edges(),
        if quick { ", quick" } else { "" }
    );

    let mut samples = Vec::new();
    samples.push(bench("induce_subgraph CSR-native (1 chunk of 4)", iters(100), || {
        let _ = induce_subgraph(g, &plan.chunks[0]);
    }));
    samples.push(bench("induce via edge list + revalidate (old)", iters(100), || {
        let _ = induce_via_edge_list(g, &plan.chunks[0]);
    }));
    let induced = plan.induce_all(g);
    samples.push(bench("lossy_union CSR merge (4 chunks)", iters(100), || {
        let _ = lossy_union_from_induced(g.num_nodes(), &induced);
    }));
    samples.push(bench("lossy_union via edge list (old)", iters(100), || {
        let mut edges = Vec::new();
        for sub in &induced {
            for (a, b) in sub.graph.edges() {
                edges.push((sub.nodes[a as usize], sub.nodes[b as usize]));
            }
        }
        let _ = Graph::from_undirected_edges(g.num_nodes(), &edges).unwrap();
    }));
    samples.push(bench("EllGraph::from_graph (chunk sub-graph)", iters(100), || {
        let _ = EllGraph::from_graph(&sub.graph, profile.ell_k).unwrap();
    }));
    samples.push(bench("CooGraph::from_graph (chunk sub-graph)", iters(100), || {
        let _ = CooGraph::from_graph(&sub.graph, e_cap).unwrap();
    }));
    samples.push(bench("prepare_microbatches serial (paper)", iters(30), || {
        let _ = prepare_microbatches(&ds, &plan, "ell", &train_mask).unwrap();
    }));
    samples.push(bench("prepare_microbatches_parallel", iters(30), || {
        let _ =
            prepare_microbatches_parallel(&ds, &plan, "ell", &train_mask).unwrap();
    }));

    let mut pool = MicrobatchPool::new();
    pool.rebuild(&ds, &plan, "ell", &train_mask).unwrap();
    samples.push(bench("MicrobatchPool::rebuild (steady state)", iters(30), || {
        pool.rebuild(&ds, &plan, "ell", &train_mask).unwrap();
    }));

    let cache = MicrobatchCache::new();
    cache
        .get_or_build(&ds, &plan, "ell", &train_mask, None)
        .unwrap();
    samples.push(bench("MicrobatchCache hit", iters(1000), || {
        let _ = cache
            .get_or_build(&ds, &plan, "ell", &train_mask, None)
            .unwrap();
    }));

    // Snapshot for the perf trajectory: BENCH_prep.json at the repo root.
    let extras = [
        ("dataset", "\"pubmed\"".to_string()),
        ("quick", quick.to_string()),
        ("chunks", chunks.to_string()),
    ];
    write_snapshot(&cfg.root.join("BENCH_prep.json"), "prep", &extras, &samples);
}
