//! Optimisers over flat host parameter vectors.
//!
//! The compiled HLO computes gradients; the coordinator owns the
//! optimiser state (exactly the split the pipeline path needs, since
//! gradients from micro-batches must be accumulated before one update).
//! Adam matches the GAT reference setup (lr 5e-3, weight decay 5e-4).
//!
//! [`allreduce`] is the cross-replica half of that split: when
//! `--replicas R` runs R pipelines over graph partitions, its
//! deterministic tree reduction folds the per-replica gradient sums
//! into one vector *before* the single optimiser step, with a fixed
//! summation order so training is bit-reproducible at any R.

pub mod allreduce;

mod adam;
mod sgd;

pub use adam::{Adam, AdamState};
pub use allreduce::{tree_allreduce, tree_allreduce_sharded, tree_rounds};
pub use sgd::Sgd;

use crate::runtime::HostTensor;

/// A first-order optimiser stepping named f32 parameter tensors.
pub trait Optimizer {
    /// Apply one update step. `params` and `grads` are parallel slices
    /// ordered by the manifest's `param_order`.
    fn step(&mut self, params: &mut [HostTensor], grads: &[HostTensor]) -> anyhow::Result<()>;
    fn name(&self) -> &'static str;
}

/// Decoupled weight decay applied to matrix parameters only (biases and
/// attention vectors exempt, as in the GAT reference implementation).
pub(crate) fn is_decayed(shape: &[usize]) -> bool {
    shape.len() >= 2
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shared harness: optimisers must minimise a convex quadratic.
    pub(crate) fn converges_on_quadratic(opt: &mut dyn Optimizer, tol: f64, iters: usize) {
        // f(w) = 0.5 * sum((w - t)^2), grad = w - t
        let target = [3.0f32, -1.5, 0.25, 8.0];
        let mut params = vec![HostTensor::f32(vec![4], vec![0.0; 4])];
        for _ in 0..iters {
            let w = params[0].as_f32().unwrap();
            let g: Vec<f32> = w.iter().zip(target).map(|(w, t)| w - t).collect();
            let grads = vec![HostTensor::f32(vec![4], g)];
            opt.step(&mut params, &grads).unwrap();
        }
        let w = params[0].as_f32().unwrap();
        for (wi, ti) in w.iter().zip(target) {
            assert!(
                (wi - ti).abs() < tol as f32,
                "{} did not converge: {wi} vs {ti}",
                opt.name()
            );
        }
    }
}
