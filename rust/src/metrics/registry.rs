//! Process-wide metrics registry: named counters, gauges, and
//! histograms behind a single [`global`] handle, with a
//! Prometheus-text dump (`--metrics-out metrics.prom`).
//!
//! This unifies the scattered report prints: anything a subsystem
//! counts or times mid-run lands here under a stable name, and the
//! CLIs read the same numbers back instead of recomputing them from
//! private fields. Unlike [`crate::trace`] events — whose sequences
//! are deterministic by contract — registry values may record *racy
//! facts* (which fleet replica won a shared cache build, how many
//! transient retries fired); that is exactly why they live here and
//! not in the trace.
//!
//! Names are free-form internally; [`Registry::prometheus_text`]
//! sanitizes them to the `[a-zA-Z_][a-zA-Z0-9_]*` metric-name grammar
//! at dump time. Output is BTreeMap-ordered, so a dump is a
//! deterministic function of the recorded values.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Mutex, OnceLock};

use anyhow::Result;

use super::percentiles;

/// A named-metrics store. Most code uses the process-wide [`global`]
/// registry; tests can build their own.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    hists: Mutex<BTreeMap<String, Vec<f64>>>,
}

/// The process-wide registry.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::default)
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Increment a counter by 1.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Increment a counter by `delta`.
    pub fn add(&self, name: &str, delta: u64) {
        let mut c = self.counters.lock().unwrap();
        *c.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Read a counter (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    /// Set a gauge to the latest value.
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), value);
    }

    /// Read a gauge (None if never set).
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Append one observation to a histogram.
    pub fn observe(&self, name: &str, value: f64) {
        self.hists
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .push(value);
    }

    /// Snapshot a histogram's observations in insertion order.
    pub fn histogram(&self, name: &str) -> Vec<f64> {
        self.hists
            .lock()
            .unwrap()
            .get(name)
            .cloned()
            .unwrap_or_default()
    }

    /// Remove one metric (all kinds) by name — e.g. a trainer clearing
    /// its epoch histogram before a fresh run in the same process.
    pub fn clear(&self, name: &str) {
        self.counters.lock().unwrap().remove(name);
        self.gauges.lock().unwrap().remove(name);
        self.hists.lock().unwrap().remove(name);
    }

    /// Drop every metric. Tests and back-to-back CLI runs.
    pub fn reset(&self) {
        self.counters.lock().unwrap().clear();
        self.gauges.lock().unwrap().clear();
        self.hists.lock().unwrap().clear();
    }

    /// Render the Prometheus text exposition format: counters and
    /// gauges as single samples, histograms as summaries (p50/p95/p99
    /// quantiles plus `_sum`/`_count`). Deterministic: metrics are
    /// name-sorted and values printed with fixed precision.
    pub fn prometheus_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for (name, v) in self.counters.lock().unwrap().iter() {
            let name = sanitize(name);
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        for (name, v) in self.gauges.lock().unwrap().iter() {
            let name = sanitize(name);
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v:.9}");
        }
        for (name, xs) in self.hists.lock().unwrap().iter() {
            let name = sanitize(name);
            let p = percentiles(xs, &[50.0, 95.0, 99.0]);
            let _ = writeln!(out, "# TYPE {name} summary");
            let _ = writeln!(out, "{name}{{quantile=\"0.5\"}} {:.9}", p[0]);
            let _ = writeln!(out, "{name}{{quantile=\"0.95\"}} {:.9}", p[1]);
            let _ = writeln!(out, "{name}{{quantile=\"0.99\"}} {:.9}", p[2]);
            let _ = writeln!(out, "{name}_sum {:.9}", xs.iter().sum::<f64>());
            let _ = writeln!(out, "{name}_count {}", xs.len());
        }
        out
    }

    /// Write [`Self::prometheus_text`] atomically to `path`.
    pub fn write_prometheus(&self, path: &Path) -> Result<()> {
        crate::util::fsio::atomic_write_str(path, &self.prometheus_text())
    }
}

/// Map an internal metric name onto the Prometheus name grammar.
fn sanitize(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    if s.chars().next().map_or(true, |c| c.is_ascii_digit()) {
        s.insert(0, '_');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_histograms_round_trip() {
        let r = Registry::new();
        assert_eq!(r.counter("served"), 0);
        r.inc("served");
        r.add("served", 4);
        assert_eq!(r.counter("served"), 5);
        assert_eq!(r.gauge("depth"), None);
        r.set_gauge("depth", 3.5);
        r.set_gauge("depth", 2.0);
        assert_eq!(r.gauge("depth"), Some(2.0));
        r.observe("epoch_s", 1.0);
        r.observe("epoch_s", 3.0);
        assert_eq!(r.histogram("epoch_s"), vec![1.0, 3.0]);
        r.clear("epoch_s");
        assert!(r.histogram("epoch_s").is_empty());
        assert_eq!(r.counter("served"), 5, "clear() is per-name");
        r.reset();
        assert_eq!(r.counter("served"), 0);
    }

    #[test]
    fn prometheus_text_is_deterministic_and_well_formed() {
        let r = Registry::new();
        r.add("b_total", 2);
        r.add("a_total", 1);
        r.set_gauge("util", 0.5);
        for v in [1.0, 2.0, 3.0, 4.0] {
            r.observe("lat_s", v);
        }
        let text = r.prometheus_text();
        assert_eq!(text, r.prometheus_text(), "dump must be stable");
        // Counters are name-sorted.
        let a = text.find("a_total 1").unwrap();
        let b = text.find("b_total 2").unwrap();
        assert!(a < b);
        assert!(text.contains("# TYPE util gauge"));
        assert!(text.contains("# TYPE lat_s summary"));
        assert!(text.contains("lat_s{quantile=\"0.5\"} 2.000000000"));
        assert!(text.contains("lat_s_sum 10.000000000"));
        assert!(text.contains("lat_s_count 4"));
    }

    #[test]
    fn names_are_sanitized_to_the_metric_grammar() {
        assert_eq!(sanitize("pipeline.epoch-s"), "pipeline_epoch_s");
        assert_eq!(sanitize("99th"), "_99th");
        assert_eq!(sanitize(""), "_");
        let r = Registry::new();
        r.inc("serve/admit");
        assert!(r.prometheus_text().contains("serve_admit 1"));
    }

    #[test]
    fn global_registry_is_shared() {
        // Use a name no other test or subsystem touches.
        global().clear("registry_selftest_total");
        global().inc("registry_selftest_total");
        assert_eq!(global().counter("registry_selftest_total"), 1);
        global().clear("registry_selftest_total");
    }
}
