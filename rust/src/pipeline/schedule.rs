//! Pipeline schedules: per-worker ordered event streams.
//!
//! A [`Schedule`] turns (stage index, stage count, micro-batch count)
//! into the exact sequence of [`StageEvent`]s one worker executes. The
//! real engine runs the events through compiled executables; the
//! simulator replays the same events against projected stage times, so
//! both price the same bubble structure.
//!
//! Three schedules ship:
//!
//! * [`FillDrain`] — GPipe: every stage runs all forwards, then all
//!   backwards. Bubble fraction on uniform stage times is the classic
//!   `(S-1)/(M+S-1)`.
//! * [`OneFOneB`] — PipeDream-flush: stage `s` warms up with `S-1-s`
//!   forwards, then alternates one-forward-one-backward, then drains.
//!   Same bubble as fill-drain on uniform stages, but peak activation
//!   stash drops from `M` to `S-s` micro-batches per stage.
//! * [`ServeStream`] — the forward-only serving schedule: every stage
//!   runs `Fwd(0..M)` back to back and no backward ever happens. With a
//!   sustained stream of inference batches, every stage is busy from
//!   its first batch to its last — the fill/drain bubble amortises to
//!   the one-off pipeline fill, which is the serving regime the paper's
//!   GPipe analysis predicts is bubble-free. Only valid on forward-only
//!   specs (`PipelineSpec::forward_only`), driven through
//!   `PipelineEngine::run_forward`.
//!
//! The two training schedules keep per-stage micro-batch order FIFO in
//! each direction, so gradient accumulation order — and therefore the
//! summed gradients — are bitwise identical between them.

use std::sync::Arc;

use anyhow::Result;

/// One unit of work on a stage worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageEvent {
    /// Run the stage forward for micro-batch `m`.
    Fwd(usize),
    /// Run the stage backward for micro-batch `m`.
    Bwd(usize),
}

/// A pipeline schedule: emits the ordered work list for each worker.
pub trait Schedule: Send + Sync {
    /// Stable name, used in CLI flags, bench cache keys and reports.
    fn name(&self) -> &'static str;

    /// Ordered event list for stage `stage` of `stages`, over
    /// `microbatches` micro-batches. Training schedules must emit every
    /// micro-batch exactly once as `Fwd` and once as `Bwd`, in
    /// increasing micro-batch order within each direction (FIFO), with
    /// `Fwd(m)` preceding `Bwd(m)`. Forward-only schedules
    /// ([`ServeStream`]) emit each micro-batch exactly once as `Fwd`,
    /// FIFO, and no `Bwd` at all — the engine rejects them anywhere but
    /// the forward-only entry point.
    fn events(&self, stage: usize, stages: usize, microbatches: usize) -> Vec<StageEvent>;
}

/// GPipe's synchronous fill-drain schedule (the paper's schedule).
#[derive(Debug, Clone, Copy, Default)]
pub struct FillDrain;

impl Schedule for FillDrain {
    fn name(&self) -> &'static str {
        "fill-drain"
    }

    fn events(&self, _stage: usize, _stages: usize, microbatches: usize) -> Vec<StageEvent> {
        (0..microbatches)
            .map(StageEvent::Fwd)
            .chain((0..microbatches).map(StageEvent::Bwd))
            .collect()
    }
}

/// One-forward-one-backward (PipeDream-flush style) with a synchronous
/// flush at the end of the step: same gradients as [`FillDrain`], lower
/// peak activation memory, never a larger bubble.
#[derive(Debug, Clone, Copy, Default)]
pub struct OneFOneB;

impl Schedule for OneFOneB {
    fn name(&self) -> &'static str {
        "1f1b"
    }

    fn events(&self, stage: usize, stages: usize, microbatches: usize) -> Vec<StageEvent> {
        let m = microbatches;
        let warmup = (stages - 1 - stage).min(m);
        let mut ev = Vec::with_capacity(2 * m);
        for i in 0..warmup {
            ev.push(StageEvent::Fwd(i));
        }
        for i in warmup..m {
            ev.push(StageEvent::Fwd(i));
            ev.push(StageEvent::Bwd(i - warmup));
        }
        for i in (m - warmup)..m {
            ev.push(StageEvent::Bwd(i));
        }
        ev
    }
}

/// Forward-only streaming schedule for the serving subsystem: each
/// stage simply runs every batch's forward in arrival order. No
/// warm-up, no drain, no backward — batch `m+1` enters stage 0 while
/// batch `m` occupies stage 1, so under sustained load all stages stay
/// busy across batch boundaries (the continuous-stream regime where
/// GPipe's bubble is a one-off fill, not a per-batch cost).
///
/// Not a training schedule: `parse_schedule` (the `--schedule` flag)
/// deliberately does not accept it, and `PipelineEngine::run_epoch`
/// rejects forward-only specs. Use `PipelineEngine::run_forward`.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStream;

impl Schedule for ServeStream {
    fn name(&self) -> &'static str {
        "serve"
    }

    fn events(&self, _stage: usize, _stages: usize, microbatches: usize) -> Vec<StageEvent> {
        (0..microbatches).map(StageEvent::Fwd).collect()
    }
}

/// Parse a `--schedule` CLI value (or the `schedule` key of
/// `configs/pipeline.json`) into a schedule instance.
pub fn parse_schedule(name: &str) -> Result<Arc<dyn Schedule>> {
    match name {
        "fill-drain" | "filldrain" | "gpipe" => Ok(Arc::new(FillDrain)),
        "1f1b" | "one-f-one-b" | "pipedream" => Ok(Arc::new(OneFOneB)),
        other => anyhow::bail!(
            "unknown schedule {other:?} (expected \"fill-drain\" or \"1f1b\")"
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The contract every schedule must satisfy (see [`Schedule::events`]).
    fn check_contract(sched: &dyn Schedule, stages: usize, m: usize) {
        for s in 0..stages {
            let ev = sched.events(s, stages, m);
            assert_eq!(ev.len(), 2 * m, "{} stage {s}: wrong length", sched.name());
            let fwd: Vec<usize> = ev
                .iter()
                .filter_map(|e| match e {
                    StageEvent::Fwd(i) => Some(*i),
                    StageEvent::Bwd(_) => None,
                })
                .collect();
            let bwd: Vec<usize> = ev
                .iter()
                .filter_map(|e| match e {
                    StageEvent::Bwd(i) => Some(*i),
                    StageEvent::Fwd(_) => None,
                })
                .collect();
            let expect: Vec<usize> = (0..m).collect();
            assert_eq!(fwd, expect, "{} stage {s}: fwd not FIFO", sched.name());
            assert_eq!(bwd, expect, "{} stage {s}: bwd not FIFO", sched.name());
            // Bwd(i) never precedes Fwd(i) on the same stage.
            for i in 0..m {
                let fpos = ev.iter().position(|e| *e == StageEvent::Fwd(i)).unwrap();
                let bpos = ev.iter().position(|e| *e == StageEvent::Bwd(i)).unwrap();
                assert!(fpos < bpos, "{} stage {s}: Bwd({i}) before Fwd({i})", sched.name());
            }
        }
    }

    #[test]
    fn both_schedules_satisfy_the_contract() {
        for stages in [2usize, 3, 4, 6] {
            for m in [1usize, 2, 3, 4, 8] {
                check_contract(&FillDrain, stages, m);
                check_contract(&OneFOneB, stages, m);
            }
        }
    }

    #[test]
    fn fill_drain_runs_all_forwards_before_any_backward() {
        for stages in [2usize, 4] {
            for m in [1usize, 4, 8] {
                for s in 0..stages {
                    let ev = FillDrain.events(s, stages, m);
                    let first_bwd = ev
                        .iter()
                        .position(|e| matches!(e, StageEvent::Bwd(_)))
                        .unwrap();
                    assert_eq!(first_bwd, m, "stage {s}: backward before the drain");
                }
            }
        }
    }

    #[test]
    fn one_f_one_b_interleaves_after_warmup() {
        use StageEvent::{Bwd, Fwd};
        // Stage 2 of 4 (warm-up 1): F0 | F1 B0 F2 B1 F3 B2 | B3.
        let ev = OneFOneB.events(2, 4, 4);
        assert_eq!(
            ev,
            vec![Fwd(0), Fwd(1), Bwd(0), Fwd(2), Bwd(1), Fwd(3), Bwd(2), Bwd(3)]
        );
        // Final stage (warm-up 0) strictly alternates.
        let ev = OneFOneB.events(3, 4, 3);
        assert_eq!(ev, vec![Fwd(0), Bwd(0), Fwd(1), Bwd(1), Fwd(2), Bwd(2)]);
        // First stage (warm-up 3) looks like fill-drain at M=4.
        let ev = OneFOneB.events(0, 4, 4);
        assert_eq!(ev, FillDrain.events(0, 4, 4));
    }

    #[test]
    fn one_f_one_b_degenerates_when_microbatches_fit_in_warmup() {
        // M=2 at stage 0 of 4: warm-up truncates to M; all F then all B.
        let ev = OneFOneB.events(0, 4, 2);
        assert_eq!(ev, FillDrain.events(0, 4, 2));
    }

    #[test]
    fn parse_schedule_names() {
        assert_eq!(parse_schedule("fill-drain").unwrap().name(), "fill-drain");
        assert_eq!(parse_schedule("gpipe").unwrap().name(), "fill-drain");
        assert_eq!(parse_schedule("1f1b").unwrap().name(), "1f1b");
        assert_eq!(parse_schedule("one-f-one-b").unwrap().name(), "1f1b");
        assert!(parse_schedule("round-robin").is_err());
        // ServeStream is not a training schedule and must not parse.
        assert!(parse_schedule("serve").is_err());
    }

    #[test]
    fn serve_stream_is_forward_only_fifo() {
        for stages in [2usize, 4] {
            for m in [1usize, 3, 8] {
                for s in 0..stages {
                    let ev = ServeStream.events(s, stages, m);
                    let expect: Vec<StageEvent> =
                        (0..m).map(StageEvent::Fwd).collect();
                    assert_eq!(ev, expect, "stage {s} of {stages}, m={m}");
                }
            }
        }
    }
}
