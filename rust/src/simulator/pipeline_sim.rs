//! Discrete-event timeline of a scheduled pipeline step.
//!
//! Replays the exact dependency structure of `pipeline::engine` by
//! executing, per stage, the same [`Schedule`] event stream the real
//! workers run:
//!
//! * forward (m, s) starts after forward (m, s-1) has arrived over the
//!   stage link AND after this stage finished its previous event;
//! * backward (m, s) starts after the cotangent (m, s+1) arrived (the
//!   final stage's backward only needs its own forward);
//! * stages with a graph input (the GAT layers) additionally stall for
//!   the *host re-build round trip* when micro-batching is on: the
//!   paper's §7.2 device→host node-tensor copy, host sub-graph re-build,
//!   host→device sub-graph upload. That term is charged per micro-batch
//!   per graph-consuming stage, exactly where the paper pays it.
//!
//! The simulator returns per-device busy time alongside the makespan so
//! the bench harness can report pipeline bubble fractions — per
//! schedule: [`simulate_pipeline_with`] prices GPipe fill-drain and
//! 1F1B (or any other [`Schedule`]) on identical stage times.

use crate::pipeline::{FillDrain, Schedule, StageEvent};

/// Per-stage, per-micro-batch inputs to the timeline.
#[derive(Debug, Clone)]
pub struct PipelineSimInput {
    /// fwd_s[stage][m]: projected stage-forward seconds.
    pub fwd_s: Vec<Vec<f64>>,
    /// bwd_s[stage][m]: projected stage-backward seconds.
    pub bwd_s: Vec<Vec<f64>>,
    /// xfer_fwd_s[boundary][m]: activation transfer seconds, stage s->s+1.
    pub xfer_fwd_s: Vec<Vec<f64>>,
    /// xfer_bwd_s[boundary][m]: cotangent transfer seconds, stage s+1->s.
    pub xfer_bwd_s: Vec<Vec<f64>>,
    /// rebuild_s[stage][m]: host round-trip stall before fwd (m, stage)
    /// (zero for stages without graph inputs or when chunks == 1*).
    pub rebuild_s: Vec<Vec<f64>>,
}

#[derive(Debug, Clone)]
pub struct PipelineSimReport {
    /// End-to-end step time (one optimiser step over all micro-batches).
    pub makespan_s: f64,
    /// Per-device busy seconds.
    pub busy_s: Vec<f64>,
    /// 1 - mean(busy)/makespan: the pipeline bubble + stall fraction.
    pub bubble_fraction: f64,
}

/// Price the GPipe fill-drain schedule (the paper's configuration).
pub fn simulate_pipeline(input: &PipelineSimInput) -> PipelineSimReport {
    simulate_pipeline_with(input, &FillDrain)
}

/// Price one pipeline step under an arbitrary [`Schedule`].
///
/// Each stage executes its event list in order; an event waits for its
/// cross-stage dependency, then occupies the device. Work-conserving
/// within the list order — exactly what the real engine's generic
/// worker does.
pub fn simulate_pipeline_with(
    input: &PipelineSimInput,
    schedule: &dyn Schedule,
) -> PipelineSimReport {
    let stages = input.fwd_s.len();
    assert!(stages >= 1);
    let m_count = input.fwd_s[0].len();
    assert!(input.bwd_s.len() == stages);
    assert!(input.xfer_fwd_s.len() == stages - 1);
    assert!(input.xfer_bwd_s.len() == stages - 1);
    assert!(input.rebuild_s.len() == stages);

    let events: Vec<Vec<StageEvent>> = (0..stages)
        .map(|s| schedule.events(s, stages, m_count))
        .collect();

    let mut fwd_end = vec![vec![0.0f64; m_count]; stages];
    let mut bwd_end = vec![vec![0.0f64; m_count]; stages];
    let mut fwd_done = vec![vec![false; m_count]; stages];
    let mut bwd_done = vec![vec![false; m_count]; stages];
    let mut clock = vec![0.0f64; stages];
    let mut busy = vec![0.0f64; stages];
    let mut next = vec![0usize; stages];
    let total: usize = events.iter().map(Vec::len).sum();
    let mut executed = 0usize;

    while executed < total {
        let mut progressed = false;
        for s in 0..stages {
            while next[s] < events[s].len() {
                // Cross-stage dependency: the time this event's input is
                // available on device s, or None if not yet produced.
                let ready = match events[s][next[s]] {
                    StageEvent::Fwd(m) => {
                        if s == 0 {
                            Some(0.0)
                        } else if fwd_done[s - 1][m] {
                            Some(fwd_end[s - 1][m] + input.xfer_fwd_s[s - 1][m])
                        } else {
                            None
                        }
                    }
                    StageEvent::Bwd(m) => {
                        if s == stages - 1 {
                            // The loss backward needs only this stage's
                            // own forward for m.
                            fwd_done[s][m].then_some(fwd_end[s][m])
                        } else if bwd_done[s + 1][m] {
                            Some(bwd_end[s + 1][m] + input.xfer_bwd_s[s][m])
                        } else {
                            None
                        }
                    }
                };
                let Some(ready) = ready else { break };
                let start = clock[s].max(ready);
                match events[s][next[s]] {
                    StageEvent::Fwd(m) => {
                        // The re-build round trip stalls the device but
                        // is idle (host) time, not busy time.
                        clock[s] = start + input.rebuild_s[s][m] + input.fwd_s[s][m];
                        busy[s] += input.fwd_s[s][m];
                        fwd_end[s][m] = clock[s];
                        fwd_done[s][m] = true;
                    }
                    StageEvent::Bwd(m) => {
                        clock[s] = start + input.bwd_s[s][m];
                        busy[s] += input.bwd_s[s][m];
                        bwd_end[s][m] = clock[s];
                        bwd_done[s][m] = true;
                    }
                }
                next[s] += 1;
                executed += 1;
                progressed = true;
            }
        }
        assert!(
            progressed,
            "schedule {:?} deadlocked: no stage can make progress",
            schedule.name()
        );
    }

    let makespan = clock.iter().copied().fold(0.0f64, f64::max);
    let mean_busy: f64 = busy.iter().sum::<f64>() / stages as f64;
    PipelineSimReport {
        makespan_s: makespan,
        bubble_fraction: 1.0 - (mean_busy / makespan.max(1e-12)),
        busy_s: busy,
    }
}

impl PipelineSimInput {
    /// Uniform helper for tests/benches: same time per stage/microbatch.
    pub fn uniform(
        stages: usize,
        m_count: usize,
        fwd: f64,
        bwd: f64,
        xfer: f64,
        rebuild: f64,
    ) -> PipelineSimInput {
        PipelineSimInput {
            fwd_s: vec![vec![fwd; m_count]; stages],
            bwd_s: vec![vec![bwd; m_count]; stages],
            xfer_fwd_s: vec![vec![xfer; m_count]; stages.saturating_sub(1)],
            xfer_bwd_s: vec![vec![xfer; m_count]; stages.saturating_sub(1)],
            rebuild_s: vec![vec![rebuild; m_count]; stages],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::OneFOneB;

    #[test]
    fn single_stage_single_batch() {
        let inp = PipelineSimInput::uniform(1, 1, 2.0, 3.0, 0.0, 0.0);
        let r = simulate_pipeline(&inp);
        assert!((r.makespan_s - 5.0).abs() < 1e-12);
        assert!(r.bubble_fraction.abs() < 1e-12);
    }

    #[test]
    fn classic_gpipe_bubble_formula() {
        // Uniform stage times, no transfers: makespan = (M + S - 1) * (f + b)
        let (s, m, f, b) = (4usize, 8usize, 1.0, 2.0);
        let inp = PipelineSimInput::uniform(s, m, f, b, 0.0, 0.0);
        let r = simulate_pipeline(&inp);
        let expect = (m as f64 + s as f64 - 1.0) * (f + b);
        assert!(
            (r.makespan_s - expect).abs() < 1e-9,
            "makespan {} != {expect}",
            r.makespan_s
        );
        // Bubble fraction = (S-1)/(M+S-1)
        let expect_bubble = (s as f64 - 1.0) / (m as f64 + s as f64 - 1.0);
        assert!((r.bubble_fraction - expect_bubble).abs() < 1e-9);
    }

    #[test]
    fn fill_drain_bubble_matches_closed_form_across_shapes() {
        // The GPipe bubble (S-1)/(M+S-1) must hold for every uniform
        // (stages, micro-batches) combination, not just the paper's.
        for s in [2usize, 3, 4, 6] {
            for m in [1usize, 2, 4, 8, 16] {
                let inp = PipelineSimInput::uniform(s, m, 0.7, 1.3, 0.0, 0.0);
                let r = simulate_pipeline_with(&inp, &FillDrain);
                let expect = (s as f64 - 1.0) / (m as f64 + s as f64 - 1.0);
                assert!(
                    (r.bubble_fraction - expect).abs() < 1e-9,
                    "S={s} M={m}: bubble {} != {expect}",
                    r.bubble_fraction
                );
            }
        }
    }

    #[test]
    fn one_f_one_b_never_worse_than_fill_drain() {
        for s in [2usize, 3, 4, 6] {
            for m in [1usize, 2, 3, 4, 8] {
                for (f, b, xfer, rebuild) in [
                    (1.0, 2.0, 0.0, 0.0),
                    (1.0, 1.0, 0.25, 0.0),
                    (2.0, 1.0, 0.1, 0.3),
                ] {
                    let inp = PipelineSimInput::uniform(s, m, f, b, xfer, rebuild);
                    let fd = simulate_pipeline_with(&inp, &FillDrain);
                    let ob = simulate_pipeline_with(&inp, &OneFOneB);
                    assert!(
                        ob.makespan_s <= fd.makespan_s + 1e-9,
                        "S={s} M={m} f={f} b={b}: 1f1b {} > fill-drain {}",
                        ob.makespan_s,
                        fd.makespan_s
                    );
                    // Busy time is schedule-invariant (same work).
                    for (a, b) in ob.busy_s.iter().zip(&fd.busy_s) {
                        assert!((a - b).abs() < 1e-12);
                    }
                }
            }
        }
    }

    #[test]
    fn more_microbatches_amortise_the_bubble() {
        let mk = |m: usize| {
            simulate_pipeline(&PipelineSimInput::uniform(4, m, 1.0, 2.0, 0.0, 0.0))
        };
        let b2 = mk(2).bubble_fraction;
        let b8 = mk(8).bubble_fraction;
        let b32 = mk(32).bubble_fraction;
        assert!(b2 > b8 && b8 > b32);
    }

    #[test]
    fn rebuild_stalls_extend_makespan_but_not_busy() {
        let base = simulate_pipeline(&PipelineSimInput::uniform(4, 4, 1.0, 2.0, 0.0, 0.0));
        let stalled =
            simulate_pipeline(&PipelineSimInput::uniform(4, 4, 1.0, 2.0, 0.0, 0.5));
        assert!(stalled.makespan_s > base.makespan_s + 0.5);
        assert_eq!(stalled.busy_s, base.busy_s);
        assert!(stalled.bubble_fraction > base.bubble_fraction);
    }

    #[test]
    fn transfers_serialise_the_fill() {
        let no_xfer = simulate_pipeline(&PipelineSimInput::uniform(4, 1, 1.0, 1.0, 0.0, 0.0));
        let xfer = simulate_pipeline(&PipelineSimInput::uniform(4, 1, 1.0, 1.0, 0.25, 0.0));
        // single micro-batch: every boundary crossed twice (fwd + bwd)
        let expect = no_xfer.makespan_s + 0.25 * 6.0;
        assert!((xfer.makespan_s - expect).abs() < 1e-9);
    }

    #[test]
    fn monotone_in_work() {
        let a = simulate_pipeline(&PipelineSimInput::uniform(4, 3, 1.0, 2.0, 0.1, 0.0));
        let b = simulate_pipeline(&PipelineSimInput::uniform(4, 3, 1.5, 2.5, 0.1, 0.0));
        assert!(b.makespan_s > a.makespan_s);
    }
}
