//! Adam with decoupled weight decay (AdamW-style, matrices only).

use anyhow::Result;

use super::{is_decayed, Optimizer};
use crate::runtime::HostTensor;

#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f64, beta1: f64, beta2: f64, eps: f64, weight_decay: f64) -> Adam {
        Adam { lr, beta1, beta2, eps, weight_decay, t: 0, m: Vec::new(), v: Vec::new() }
    }

    pub fn from_config(mc: &crate::config::ModelConfig) -> Adam {
        Adam::new(mc.lr, mc.beta1, mc.beta2, mc.eps, mc.weight_decay)
    }

    fn ensure_state(&mut self, params: &[HostTensor]) {
        if self.m.is_empty() {
            self.m = params.iter().map(|p| vec![0.0; p.elements()]).collect();
            self.v = params.iter().map(|p| vec![0.0; p.elements()]).collect();
        }
    }

    /// Snapshot the optimizer's mutable state (step count + first/second
    /// moments) for checkpointing. The hyper-parameters are NOT included
    /// — they come from config and re-apply on restore.
    pub fn export_state(&self) -> AdamState {
        AdamState { t: self.t, m: self.m.clone(), v: self.v.clone() }
    }

    /// Restore a state captured by [`Adam::export_state`]. A resumed
    /// optimizer continues the moment recursions bit-identically to the
    /// uninterrupted run (the step math touches only f32/u64 state that
    /// round-trips exactly).
    pub fn import_state(&mut self, state: AdamState) {
        self.t = state.t;
        self.m = state.m;
        self.v = state.v;
    }
}

/// The checkpointable part of [`Adam`]: everything `step` mutates.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdamState {
    /// Completed step count (drives the bias-correction terms).
    pub t: u64,
    /// Per-parameter first-moment estimates.
    pub m: Vec<Vec<f32>>,
    /// Per-parameter second-moment estimates.
    pub v: Vec<Vec<f32>>,
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut [HostTensor], grads: &[HostTensor]) -> Result<()> {
        anyhow::ensure!(params.len() == grads.len(), "param/grad arity mismatch");
        self.ensure_state(params);
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let (b1, b2) = (self.beta1 as f32, self.beta2 as f32);
        let lr = self.lr as f32;
        let eps = self.eps as f32;

        for (i, (p, g)) in params.iter_mut().zip(grads).enumerate() {
            let decay = if is_decayed(p.shape()) { self.weight_decay as f32 } else { 0.0 };
            let g = g.as_f32()?;
            let w = p.as_f32_mut()?;
            anyhow::ensure!(w.len() == g.len(), "param {i} size mismatch");
            let m = &mut self.m[i];
            let v = &mut self.v[i];
            for j in 0..w.len() {
                // L2-style decay folded into the gradient (GAT reference
                // uses torch Adam's weight_decay, which is coupled).
                let gj = g[j] + decay * w[j];
                m[j] = b1 * m[j] + (1.0 - b1) * gj;
                v[j] = b2 * v[j] + (1.0 - b2) * gj * gj;
                let mhat = m[j] / b1t as f32;
                let vhat = v[j] / b2t as f32;
                w[j] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
        Ok(())
    }

    fn name(&self) -> &'static str {
        "adam"
    }
}

#[cfg(test)]
mod tests {
    use super::super::tests::converges_on_quadratic;
    use super::*;

    #[test]
    fn converges() {
        let mut adam = Adam::new(0.1, 0.9, 0.999, 1e-8, 0.0);
        converges_on_quadratic(&mut adam, 0.02, 500);
    }

    #[test]
    fn bias_correction_first_step() {
        // After one step from zero state, update must be ~lr * sign(g).
        let mut adam = Adam::new(0.01, 0.9, 0.999, 1e-8, 0.0);
        let mut p = vec![HostTensor::f32(vec![2], vec![1.0, -1.0])];
        let g = vec![HostTensor::f32(vec![2], vec![0.5, -2.0])];
        adam.step(&mut p, &g).unwrap();
        let w = p[0].as_f32().unwrap();
        assert!((w[0] - (1.0 - 0.01)).abs() < 1e-4, "{w:?}");
        assert!((w[1] - (-1.0 + 0.01)).abs() < 1e-4, "{w:?}");
    }

    #[test]
    fn weight_decay_only_on_matrices() {
        let mut adam = Adam::new(0.01, 0.9, 0.999, 1e-8, 1.0);
        let mut p = vec![
            HostTensor::f32(vec![2, 1], vec![1.0, 1.0]), // decayed
            HostTensor::f32(vec![2], vec![1.0, 1.0]),    // bias: not
        ];
        let g = vec![
            HostTensor::f32(vec![2, 1], vec![0.0, 0.0]),
            HostTensor::f32(vec![2], vec![0.0, 0.0]),
        ];
        adam.step(&mut p, &g).unwrap();
        assert!(p[0].as_f32().unwrap()[0] < 1.0); // decay pulled it down
        assert_eq!(p[1].as_f32().unwrap()[0], 1.0); // untouched
    }

    #[test]
    fn state_round_trip_is_bit_identical() {
        // Two optimizers: one runs 4 steps straight, the other runs 2,
        // exports/imports its state into a FRESH instance, then runs the
        // remaining 2. Final params and state must match bit for bit.
        let mk = || Adam::new(0.05, 0.9, 0.999, 1e-8, 0.01);
        let grads: Vec<Vec<HostTensor>> = (0..4)
            .map(|s| {
                vec![
                    HostTensor::f32(vec![2, 1], vec![0.3 + s as f32, -0.7]),
                    HostTensor::f32(vec![2], vec![0.1, 0.2 * s as f32]),
                ]
            })
            .collect();
        let init = || {
            vec![
                HostTensor::f32(vec![2, 1], vec![1.0, -2.0]),
                HostTensor::f32(vec![2], vec![0.5, 0.25]),
            ]
        };
        let mut a = mk();
        let mut pa = init();
        for g in &grads {
            a.step(&mut pa, g).unwrap();
        }
        let mut b = mk();
        let mut pb = init();
        for g in &grads[..2] {
            b.step(&mut pb, g).unwrap();
        }
        let saved = b.export_state();
        let mut b2 = mk();
        b2.import_state(saved);
        for g in &grads[2..] {
            b2.step(&mut pb, g).unwrap();
        }
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
        }
        assert_eq!(a.export_state(), b2.export_state());
    }

    #[test]
    fn rejects_mismatched_arity() {
        let mut adam = Adam::new(0.01, 0.9, 0.999, 1e-8, 0.0);
        let mut p = vec![HostTensor::f32(vec![1], vec![0.0])];
        assert!(adam.step(&mut p, &[]).is_err());
    }
}
