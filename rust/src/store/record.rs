//! The on-disk unit of the parameter store: a named-section binary
//! record with a magic header and an FNV-1a-64 checksum footer.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! magic    8 bytes   b"GNNSTORE"
//! format   u32       currently 1
//! count    u32       number of sections
//! section  (count times)
//!   name_len  u32
//!   name      name_len bytes (utf-8)
//!   data_len  u64
//!   data      data_len bytes
//! checksum u64       fnv1a64 of every preceding byte
//! ```
//!
//! The checksum doubles as the record's **content identity**: two
//! records with the same sections hash identically, and the serving
//! path keys device-resident parameter buffers on it.

use anyhow::{Context, Result};

use crate::util::hash::fnv1a64;

/// File magic: identifies a parameter-store record.
pub const MAGIC: &[u8; 8] = b"GNNSTORE";

/// Current record format version.
pub const FORMAT: u32 = 1;

/// An ordered set of named binary sections. Typed helpers encode the
/// payloads this crate checkpoints (f32 params as bit patterns, f64
/// curves as bit patterns, u64 cursors) losslessly — a decode followed
/// by an encode reproduces the file byte for byte.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Record {
    sections: Vec<(String, Vec<u8>)>,
}

impl Record {
    pub fn new() -> Record {
        Record::default()
    }

    /// Add (or replace) a raw section.
    pub fn put_bytes(&mut self, name: &str, data: Vec<u8>) {
        if let Some(s) = self.sections.iter_mut().find(|(n, _)| n == name) {
            s.1 = data;
        } else {
            self.sections.push((name.to_string(), data));
        }
    }

    pub fn put_str(&mut self, name: &str, v: &str) {
        self.put_bytes(name, v.as_bytes().to_vec());
    }

    pub fn put_u64(&mut self, name: &str, v: u64) {
        self.put_bytes(name, v.to_le_bytes().to_vec());
    }

    pub fn put_u64s(&mut self, name: &str, vs: &[u64]) {
        let mut out = Vec::with_capacity(vs.len() * 8);
        for v in vs {
            out.extend_from_slice(&v.to_le_bytes());
        }
        self.put_bytes(name, out);
    }

    pub fn put_usizes(&mut self, name: &str, vs: &[usize]) {
        let as_u64: Vec<u64> = vs.iter().map(|&v| v as u64).collect();
        self.put_u64s(name, &as_u64);
    }

    /// f32 payloads are stored as little-endian bit patterns: the exact
    /// bits round-trip (NaNs, -0.0 and all).
    pub fn put_f32s(&mut self, name: &str, vs: &[f32]) {
        let mut out = Vec::with_capacity(vs.len() * 4);
        for v in vs {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        self.put_bytes(name, out);
    }

    /// f64 payloads as bit patterns — same lossless contract as f32.
    pub fn put_f64s(&mut self, name: &str, vs: &[f64]) {
        let mut out = Vec::with_capacity(vs.len() * 8);
        for v in vs {
            out.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        self.put_bytes(name, out);
    }

    pub fn has(&self, name: &str) -> bool {
        self.sections.iter().any(|(n, _)| n == name)
    }

    pub fn bytes(&self, name: &str) -> Result<&[u8]> {
        self.sections
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, d)| d.as_slice())
            .with_context(|| format!("record has no section {name:?}"))
    }

    pub fn str_(&self, name: &str) -> Result<&str> {
        std::str::from_utf8(self.bytes(name)?)
            .with_context(|| format!("section {name:?} is not utf-8"))
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        let b = self.bytes(name)?;
        anyhow::ensure!(b.len() == 8, "section {name:?}: want 8 bytes, got {}", b.len());
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub fn u64s(&self, name: &str) -> Result<Vec<u64>> {
        let b = self.bytes(name)?;
        anyhow::ensure!(
            b.len() % 8 == 0,
            "section {name:?}: length {} is not a multiple of 8",
            b.len()
        );
        Ok(b.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    pub fn usizes(&self, name: &str) -> Result<Vec<usize>> {
        Ok(self.u64s(name)?.into_iter().map(|v| v as usize).collect())
    }

    pub fn f32s(&self, name: &str) -> Result<Vec<f32>> {
        let b = self.bytes(name)?;
        anyhow::ensure!(
            b.len() % 4 == 0,
            "section {name:?}: length {} is not a multiple of 4",
            b.len()
        );
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    pub fn f64s(&self, name: &str) -> Result<Vec<f64>> {
        let b = self.bytes(name)?;
        anyhow::ensure!(
            b.len() % 8 == 0,
            "section {name:?}: length {} is not a multiple of 8",
            b.len()
        );
        Ok(b.chunks_exact(8)
            .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    /// Serialize to the checksummed wire format. The returned hash is
    /// the checksum footer — the record's content identity.
    pub fn encode(&self) -> (Vec<u8>, u64) {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&FORMAT.to_le_bytes());
        out.extend_from_slice(&(self.sections.len() as u32).to_le_bytes());
        for (name, data) in &self.sections {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(data.len() as u64).to_le_bytes());
            out.extend_from_slice(data);
        }
        let checksum = fnv1a64(&out);
        out.extend_from_slice(&checksum.to_le_bytes());
        (out, checksum)
    }

    /// Parse and verify a wire-format record. Fails — with a reason
    /// naming what broke — on a bad magic, an unknown format, any
    /// truncation, or a checksum mismatch; `Store::open` quarantines
    /// versions whose decode fails.
    pub fn decode(bytes: &[u8]) -> Result<Record> {
        anyhow::ensure!(
            bytes.len() >= MAGIC.len() + 4 + 4 + 8,
            "record truncated: {} bytes is smaller than an empty record",
            bytes.len()
        );
        anyhow::ensure!(
            &bytes[..MAGIC.len()] == MAGIC,
            "bad magic: not a parameter-store record"
        );
        let body_end = bytes.len() - 8;
        let stored =
            u64::from_le_bytes(bytes[body_end..].try_into().unwrap());
        let computed = fnv1a64(&bytes[..body_end]);
        anyhow::ensure!(
            stored == computed,
            "checksum mismatch: footer {stored:#018x}, computed {computed:#018x}"
        );
        let mut pos = MAGIC.len();
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            anyhow::ensure!(
                *pos + n <= body_end,
                "record truncated at offset {pos}"
            );
            let s = &bytes[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let format = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        anyhow::ensure!(format == FORMAT, "unknown record format {format}");
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
        let mut rec = Record::new();
        for _ in 0..count {
            let name_len =
                u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap());
            let name = std::str::from_utf8(take(&mut pos, name_len as usize)?)
                .context("section name is not utf-8")?
                .to_string();
            let data_len =
                u64::from_le_bytes(take(&mut pos, 8)?.try_into().unwrap());
            let data = take(&mut pos, data_len as usize)?.to_vec();
            rec.sections.push((name, data));
        }
        anyhow::ensure!(
            pos == body_end,
            "record has {} trailing bytes after the last section",
            body_end - pos
        );
        Ok(rec)
    }

    /// The content identity without materialising the encoding twice.
    pub fn content_hash(&self) -> u64 {
        self.encode().1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Record {
        let mut r = Record::new();
        r.put_str("label", "test");
        r.put_u64("epoch", 7);
        r.put_f32s("params", &[1.5, -0.0, f32::NAN, 3.25e-20]);
        r.put_f64s("curve", &[0.125, -7.5]);
        r.put_u64s("cursors", &[1, 2, 3]);
        r
    }

    #[test]
    fn encode_decode_round_trips() {
        let r = sample();
        let (bytes, hash) = r.encode();
        let back = Record::decode(&bytes).unwrap();
        assert_eq!(back.str_("label").unwrap(), "test");
        assert_eq!(back.u64("epoch").unwrap(), 7);
        let ps = back.f32s("params").unwrap();
        assert_eq!(ps[0], 1.5);
        assert!(ps[1].is_sign_negative() && ps[1] == 0.0);
        assert!(ps[2].is_nan());
        assert_eq!(back.f64s("curve").unwrap(), vec![0.125, -7.5]);
        assert_eq!(back.u64s("cursors").unwrap(), vec![1, 2, 3]);
        // Re-encoding the decoded record is byte-identical (and so has
        // the same content hash).
        let (bytes2, hash2) = back.encode();
        assert_eq!(bytes, bytes2);
        assert_eq!(hash, hash2);
    }

    #[test]
    fn decode_rejects_truncation_at_every_length() {
        let (bytes, _) = sample().encode();
        for cut in 0..bytes.len() {
            assert!(
                Record::decode(&bytes[..cut]).is_err(),
                "decode accepted a {cut}-byte prefix of a {}-byte record",
                bytes.len()
            );
        }
        assert!(Record::decode(&bytes).is_ok());
    }

    #[test]
    fn decode_rejects_any_flipped_bit() {
        let (bytes, _) = sample().encode();
        // Flip one bit at a spread of offsets (every byte would be slow
        // in debug builds; stride keeps it broad but quick).
        for off in (0..bytes.len()).step_by(7) {
            let mut bad = bytes.clone();
            bad[off] ^= 0x10;
            assert!(
                Record::decode(&bad).is_err(),
                "decode accepted a bit flip at offset {off}"
            );
        }
    }

    #[test]
    fn decode_rejects_wrong_magic_and_format() {
        let (mut bytes, _) = sample().encode();
        let mut not_magic = bytes.clone();
        not_magic[0] = b'X';
        assert!(Record::decode(&not_magic).is_err());
        // Corrupt format but fix up the checksum: the format check
        // itself must fire, not just the checksum.
        bytes[8] = 99;
        let body_end = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..body_end]).to_le_bytes();
        bytes[body_end..].copy_from_slice(&sum);
        let err = Record::decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("unknown record format"), "{err}");
    }

    #[test]
    fn put_replaces_existing_section() {
        let mut r = Record::new();
        r.put_u64("x", 1);
        r.put_u64("x", 2);
        assert_eq!(r.u64("x").unwrap(), 2);
        let (bytes, _) = r.encode();
        assert_eq!(Record::decode(&bytes).unwrap().u64("x").unwrap(), 2);
    }

    #[test]
    fn missing_section_is_a_clear_error() {
        let r = Record::new();
        let err = r.u64("nope").unwrap_err().to_string();
        assert!(err.contains("no section"), "{err}");
    }

    #[test]
    fn content_hash_tracks_content() {
        let a = sample().content_hash();
        let mut r = sample();
        r.put_u64("epoch", 8);
        assert_ne!(a, r.content_hash());
        assert_eq!(a, sample().content_hash());
    }
}
