//! Sub-graph induction: the paper's micro-batching hot spot.
//!
//! torchgpipe splits the node tensor sequentially; every GAT layer must
//! then re-build a graph over just those nodes (paper §6/7.2). Only edges
//! with BOTH endpoints inside the chunk survive — the information loss
//! behind the paper's Figure 4 accuracy collapse. `InducedSubgraph`
//! reports exactly how many edges were lost so the batching stats bench
//! (E8) can quantify it.

use super::Graph;

#[derive(Debug, Clone)]
pub struct InducedSubgraph {
    /// Re-indexed sub-graph over the chunk's nodes (0..chunk_len).
    pub graph: Graph,
    /// Original node id of each sub-graph node (the chunk, in order).
    pub nodes: Vec<u32>,
    /// Undirected edges retained (both endpoints in the chunk).
    pub kept_edges: usize,
    /// Undirected edges with exactly one endpoint in the chunk — LOST.
    pub cut_edges: usize,
}

/// Induce the sub-graph over `nodes` (original ids, unique).
///
/// O(|chunk| + sum of chunk degrees): one pass building an old->new
/// map, two passes over chunk adjacency rows emitting the induced CSR
/// directly (see [`InduceScratch::induce`]) — no intermediate edge
/// list, no per-row sort.
pub fn induce_subgraph(g: &Graph, nodes: &[u32]) -> InducedSubgraph {
    InduceScratch::new().induce(g, nodes)
}

/// Reusable induction scratch: keeps the O(|V|) old→new remap table and
/// the CSR cursor buffer alive across calls, so per-epoch sub-graph
/// rebuilds (the paper's §7.2 hot path, driven by
/// `pipeline::MicrobatchPool`) stop re-allocating and re-zeroing them
/// every chunk.
#[derive(Debug, Default)]
pub struct InduceScratch {
    remap: Vec<u32>,
    cursor: Vec<usize>,
}

impl InduceScratch {
    pub fn new() -> InduceScratch {
        InduceScratch::default()
    }

    /// Same result as [`induce_subgraph`], reusing this scratch's
    /// buffers. The remap table is restored to all-`u32::MAX` on exit by
    /// resetting only the touched entries (O(|chunk|), not O(|V|)).
    ///
    /// Emits the induced CSR directly — no intermediate edge list, no
    /// per-row sort, no duplicate re-validation (the old path paid all
    /// three through `Graph::from_undirected_edges` on every chunk,
    /// every epoch). Two passes over the chunk's adjacency rows:
    ///
    /// 1. **counting** — per new node, how many neighbours survive the
    ///    chunk boundary (plus the cut-edge tally), prefix-summed into
    ///    `indptr`;
    /// 2. **placement** — destination-major: for each new id `b` in
    ///    ascending order, append `b` to the row of every kept
    ///    neighbour. The outer loop ascends, so every row comes out
    ///    sorted without a sort — exactly the invariant
    ///    [`Graph::from_sorted_csr`] trusts. (Source-major emission
    ///    would not: the remap follows chunk order, which preserves no
    ///    global order.)
    pub fn induce(&mut self, g: &Graph, nodes: &[u32]) -> InducedSubgraph {
        let k = nodes.len();
        if self.remap.len() != g.num_nodes() {
            self.remap.clear();
            self.remap.resize(g.num_nodes(), u32::MAX);
        }
        let remap = &mut self.remap;
        for (new, &old) in nodes.iter().enumerate() {
            debug_assert!(remap[old as usize] == u32::MAX, "duplicate node in chunk");
            remap[old as usize] = new as u32;
        }

        // Pass 1: kept-degree per new node -> indptr, plus cut count.
        let mut indptr = vec![0usize; k + 1];
        let mut cut = 0usize;
        for (new_a, &old_a) in nodes.iter().enumerate() {
            for &old_b in g.neighbors(old_a as usize) {
                if remap[old_b as usize] == u32::MAX {
                    cut += 1; // counted once per direction from inside
                } else {
                    indptr[new_a + 1] += 1;
                }
            }
        }
        for i in 0..k {
            indptr[i + 1] += indptr[i];
        }

        // Pass 2: destination-major placement into sorted rows.
        self.cursor.clear();
        self.cursor.extend_from_slice(&indptr[..k]);
        let cursor = &mut self.cursor;
        let mut indices = vec![0u32; indptr[k]];
        for (new_b, &old_b) in nodes.iter().enumerate() {
            for &old_a in g.neighbors(old_b as usize) {
                let new_a = remap[old_a as usize];
                if new_a != u32::MAX {
                    indices[cursor[new_a as usize]] = new_b as u32;
                    cursor[new_a as usize] += 1;
                }
            }
        }

        // Restore the invariant for the next call.
        for &old in nodes {
            remap[old as usize] = u32::MAX;
        }
        let kept_edges = indices.len() / 2;
        InducedSubgraph {
            nodes: nodes.to_vec(),
            kept_edges,
            // Each cut undirected edge was seen once (from its inside endpoint)
            // unless both endpoints are inside (then it isn't cut at all).
            cut_edges: cut,
            graph: Graph::from_sorted_csr(k, indptr, indices),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cycle(n: usize) -> Graph {
        let edges: Vec<(u32, u32)> = (0..n)
            .map(|i| (i as u32, ((i + 1) % n) as u32))
            .collect();
        Graph::from_undirected_edges(n, &edges).unwrap()
    }

    #[test]
    fn full_set_is_identity() {
        let g = cycle(6);
        let all: Vec<u32> = (0..6).collect();
        let s = induce_subgraph(&g, &all);
        assert_eq!(s.kept_edges, 6);
        assert_eq!(s.cut_edges, 0);
        assert_eq!(s.graph.num_edges(), g.num_edges());
    }

    #[test]
    fn sequential_half_of_cycle_cuts_two() {
        let g = cycle(6);
        let s = induce_subgraph(&g, &[0, 1, 2]);
        // kept: 0-1, 1-2; cut: 2-3 and 5-0
        assert_eq!(s.kept_edges, 2);
        assert_eq!(s.cut_edges, 2);
        assert_eq!(s.graph.num_nodes(), 3);
        assert!(s.graph.has_edge(0, 1) && s.graph.has_edge(1, 2));
    }

    #[test]
    fn reindexing_is_chunk_order() {
        let g = cycle(6);
        let s = induce_subgraph(&g, &[4, 5, 0]);
        // original edges 4-5 and 5-0 survive as 0-1, 1-2
        assert_eq!(s.nodes, vec![4, 5, 0]);
        assert!(s.graph.has_edge(0, 1));
        assert!(s.graph.has_edge(1, 2));
        assert!(!s.graph.has_edge(0, 2));
    }

    #[test]
    fn isolated_chunk() {
        let g = cycle(6);
        let s = induce_subgraph(&g, &[0, 3]);
        assert_eq!(s.kept_edges, 0);
        assert_eq!(s.cut_edges, 4);
    }

    /// The CSR-native fast path must be bitwise-equal to inducing via
    /// an explicit edge list through the validating constructor (the
    /// pre-fast-path implementation), including row order.
    #[test]
    fn csr_native_matches_validating_edge_list_path() {
        let g = cycle(9);
        let chunks: &[&[u32]] = &[
            &[0, 1, 2, 3],
            &[8, 4, 6],     // remap order != id order: rows must still sort
            &[5, 7],
            &[3, 1, 8, 0, 6],
            &[2],
        ];
        for chunk in chunks {
            let fast = induce_subgraph(&g, chunk);
            // Old path: collect (a < b) edges, validate + sort per row.
            let mut remap = vec![u32::MAX; g.num_nodes()];
            for (new, &old) in chunk.iter().enumerate() {
                remap[old as usize] = new as u32;
            }
            let mut edges = Vec::new();
            for (new_a, &old_a) in chunk.iter().enumerate() {
                for &old_b in g.neighbors(old_a as usize) {
                    let new_b = remap[old_b as usize];
                    if new_b != u32::MAX && (new_a as u32) < new_b {
                        edges.push((new_a as u32, new_b));
                    }
                }
            }
            let slow = Graph::from_undirected_edges(chunk.len(), &edges).unwrap();
            assert_eq!(fast.graph, slow, "chunk {chunk:?}");
            assert_eq!(fast.kept_edges, edges.len());
        }
    }

    #[test]
    fn scratch_reuse_matches_fresh_induction() {
        let g = cycle(8);
        let chunks: &[&[u32]] = &[&[0, 1, 2], &[3, 4, 5], &[6, 7], &[1, 5, 7]];
        let mut scratch = InduceScratch::new();
        // Two passes over the same chunks: reuse must not leak remap
        // state between chunks or between passes.
        for _ in 0..2 {
            for chunk in chunks {
                let fresh = induce_subgraph(&g, chunk);
                let reused = scratch.induce(&g, chunk);
                assert_eq!(fresh.nodes, reused.nodes);
                assert_eq!(fresh.kept_edges, reused.kept_edges);
                assert_eq!(fresh.cut_edges, reused.cut_edges);
                assert_eq!(fresh.graph, reused.graph);
            }
        }
    }
}
