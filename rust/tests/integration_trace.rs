//! Tracing-subsystem invariants: the determinism contract, the
//! Chrome/Perfetto export, and the `gnn-pipe trace` analyzer.
//!
//! Host-side tests (always run, no artifacts needed) pin:
//!
//! * **event-sequence determinism** — two recordings of the same
//!   multi-replica, multi-stage workload through the real thread pool
//!   produce bit-identical [`TraceData::signature`]s (names, args,
//!   per-track ordering; timestamps excluded by construction);
//! * **export validity** — a `--trace-out` file written by
//!   [`write_chrome_trace`] parses as Chrome trace-event JSON (the
//!   format Perfetto and `chrome://tracing` load), with every event
//!   carrying `ph`/`pid`/`tid` and threads named via metadata;
//! * **analyzer round-trip** — `analyze_file` on that file reports
//!   per-stage utilization, a critical-path decomposition, and a
//!   measured-vs-model drift table.
//!
//! The end-to-end test (skipped gracefully when `make artifacts` has
//! not run) pins the acceptance contract on the real pipeline: two
//! identical (seed, config) `PipelineEngine::run_epoch` recordings
//! have bit-identical signatures, and their export analyzes into
//! utilization rows for every stage lane.

use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Duration;

use gnn_pipe::batching::{Chunker, SequentialChunker};
use gnn_pipe::config::Config;
use gnn_pipe::data::generate;
use gnn_pipe::pipeline::{
    prepare_microbatches, FillDrain, PipelineEngine, PipelineSpec,
};
use gnn_pipe::runtime::Engine;
use gnn_pipe::trace::analyze::{analyze_file, KIND_PIPELINE};
use gnn_pipe::trace::chrome::write_chrome_trace;
use gnn_pipe::trace::{self, TraceData, TID_COORD};
use gnn_pipe::train::{flatten_params, init_params};
use gnn_pipe::util::json::Json;
use gnn_pipe::util::par::run_indexed;

/// The recorder is process-global and tests in this binary run
/// concurrently: every test that starts a session holds this lock
/// (ignoring poisoning — an earlier failed test must not cascade).
fn session_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn tmp_file(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "gnn_pipe_integration_trace_{tag}_{}.json",
        std::process::id()
    ))
}

/// A deterministic stand-in for one traced run: R replicas through the
/// real index-stealing pool, each spawning one worker thread per stage
/// (exactly the engine's topology), every lane emitting the real event
/// vocabulary with args derived from (replica, stage, microbatch).
fn synthetic_run(replicas: usize, stages: usize, mbs: usize) -> TraceData {
    trace::start();
    trace::instant(
        "run_meta",
        &[
            ("kind", KIND_PIPELINE),
            ("stages", stages as i64),
            ("chunks", mbs as i64),
            ("schedule", 0),
            ("replicas", replicas as i64),
        ],
    );
    run_indexed(replicas, replicas.min(2), |r| {
        trace::set_pid(r as u32);
        let step = trace::span1("pipeline_step", "epoch", 2);
        std::thread::scope(|scope| {
            for s in 0..stages {
                scope.spawn(move || {
                    trace::bind(r as u32, s as u32);
                    for m in 0..mbs {
                        {
                            let _w =
                                trace::span1("recv_activation", "mb", m as i64);
                        }
                        let exec = trace::span1("fwd", "mb", m as i64);
                        std::thread::sleep(Duration::from_micros(200));
                        drop(exec);
                    }
                    for m in (0..mbs).rev() {
                        let exec = trace::span1("bwd", "mb", m as i64);
                        std::thread::sleep(Duration::from_micros(400));
                        drop(exec);
                        let _send =
                            trace::span1("send_cotangent", "mb", m as i64);
                    }
                });
            }
        });
        drop(step);
        trace::instant("watchdog_fire", &[("stage", 0), ("mb", r as i64)]);
    });
    trace::set_pid(0);
    trace::stop()
}

// ---------------------------------------------------------------------
// Host-side: the determinism contract.
// ---------------------------------------------------------------------

#[test]
fn event_sequences_are_deterministic_across_identical_runs() {
    let _g = session_lock();
    let a = synthetic_run(2, 3, 4);
    let b = synthetic_run(2, 3, 4);
    assert!(!a.is_empty());
    assert_eq!(
        a.signature(),
        b.signature(),
        "same (seed, config) must replay the same event sequence"
    );
    // Every logical lane got its own track: per replica, one
    // coordinator lane plus one lane per stage, replicas 0 and 1.
    let ids: Vec<(u32, u32)> =
        a.tracks.iter().map(|t| (t.pid, t.tid)).collect();
    assert_eq!(
        ids,
        vec![
            (0, 0),
            (0, 1),
            (0, 2),
            (0, TID_COORD),
            (1, 0),
            (1, 1),
            (1, 2),
            (1, TID_COORD),
        ]
    );
    // Stage lanes carry the full per-microbatch program in order.
    let sig = a.signature();
    assert!(sig.contains("B fwd mb=0"));
    assert!(sig.contains("B bwd mb=3"));
    assert!(sig.contains("I watchdog_fire stage=0 mb=1"));
}

#[test]
fn a_disabled_recorder_records_nothing_across_the_same_workload() {
    let _g = session_lock();
    assert!(trace::disabled(), "tests must leave the recorder off");
    // The same workload without start(): every call must be a no-op,
    // and a subsequent session must not inherit any of it.
    run_indexed(2, 2, |r| {
        trace::set_pid(r as u32);
        let _s = trace::span1("fwd", "mb", r as i64);
        trace::instant("watchdog_fire", &[("stage", 0)]);
    });
    trace::set_pid(0);
    trace::start();
    let data = trace::stop();
    assert!(data.is_empty(), "disabled-phase events must not leak in");
}

// ---------------------------------------------------------------------
// Host-side: export validity + analyzer round-trip.
// ---------------------------------------------------------------------

#[test]
fn chrome_export_is_valid_trace_json_and_the_analyzer_reads_it_back() {
    let data = {
        let _g = session_lock();
        synthetic_run(1, 2, 3)
    };
    let path = tmp_file("chrome_smoke");
    write_chrome_trace(&path, &data).expect("write trace");

    // The file is well-formed Chrome trace-event JSON: a traceEvents
    // array whose every entry has ph/pid/tid, with thread-name
    // metadata — the structure Perfetto / chrome://tracing load.
    let text = std::fs::read_to_string(&path).expect("read trace");
    let doc = Json::parse(&text).expect("trace file must be valid JSON");
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents array");
    assert!(events.len() > data.total_events(), "events + metadata");
    for ev in events {
        assert!(ev.get("ph").is_some());
        assert!(ev.get("pid").is_some());
        assert!(ev.get("tid").is_some());
    }
    assert!(
        events.iter().any(|e| {
            e.get("ph").and_then(Json::as_str) == Some("M")
                && e.get("args")
                    .and_then(|a| a.get("name"))
                    .and_then(Json::as_str)
                    == Some("stage 1")
        }),
        "stage lanes must be named for the timeline UI"
    );

    // The analyzer reduces the same file to the report of
    // `gnn-pipe trace`: utilization rows per stage lane, a
    // critical-path decomposition, and the drift table.
    let analysis = analyze_file(&path).expect("analyze");
    assert_eq!(analysis.stages.len(), 2);
    for row in &analysis.stages {
        assert_eq!(row.fwd_count, 3);
        assert_eq!(row.bwd_count, 3);
        assert!(row.util > 0.0 && row.util <= 1.0);
        assert!((row.util + row.bubble - 1.0).abs() < 1e-9);
    }
    assert!(analysis.bottleneck.is_some());
    assert!(
        !analysis.drift.is_empty(),
        "pipeline run_meta must yield a measured-vs-model table"
    );
    assert_eq!(analysis.instant_counts["watchdog_fire"], 1);
    let report = analysis.render();
    assert!(report.contains("run: pipeline"));
    assert!(report.contains("bubble"));
    assert!(report.contains("critical path"));

    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------------------
// End-to-end: the real pipeline under the recorder (needs artifacts).
// ---------------------------------------------------------------------

#[test]
fn real_pipeline_epochs_trace_deterministically_and_analyze() {
    let cfg = Config::load().expect("configs");
    if !cfg.artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    }
    let engine =
        Engine::from_artifacts_dir(&cfg.artifacts_dir()).expect("engine");
    let profile = cfg.dataset("pubmed").unwrap().clone();
    let ds = generate(&profile).unwrap();
    let chunks = 4usize;
    let plan = SequentialChunker.plan(&ds.graph, chunks);
    let train_mask = ds.splits.train_mask(profile.nodes);
    let mbs = prepare_microbatches(&ds, &plan, "ell", &train_mask).unwrap();
    let pipe = PipelineEngine::new(
        &engine,
        "pubmed",
        "ell",
        chunks,
        PipelineSpec::gat4(),
        std::sync::Arc::new(FillDrain),
    )
    .expect("pipeline engine");
    engine.warm_up(&pipe.artifact_names).expect("warm-up");
    let params_map = init_params(&profile, &cfg.model, 0);
    let params =
        flatten_params(&params_map, &engine.manifest.param_order).unwrap();

    // One traced run: the run_meta stamp the pipeline CLI records, then
    // two steady steps, exactly as the driver loop shapes them.
    let record = || {
        let _g = session_lock();
        trace::start();
        trace::instant(
            "run_meta",
            &[
                ("kind", KIND_PIPELINE),
                ("stages", PipelineSpec::gat4().num_stages() as i64),
                ("chunks", chunks as i64),
                ("schedule", 0),
                ("replicas", 1),
            ],
        );
        for epoch in 2..4i64 {
            let step = trace::span1("pipeline_step", "epoch", epoch);
            let _ = pipe.run_epoch(&params, &mbs, (0, 1)).unwrap();
            drop(step);
        }
        trace::stop()
    };
    let a = record();
    let b = record();
    assert_eq!(
        a.signature(),
        b.signature(),
        "identical (seed, config) pipeline runs must replay identical \
         event sequences"
    );

    // Each of the 4 stages recorded per-microbatch fwd+bwd spans on
    // its own lane, per step.
    let stages = PipelineSpec::gat4().num_stages();
    for s in 0..stages as u32 {
        let track = a
            .tracks
            .iter()
            .find(|t| (t.pid, t.tid) == (0, s))
            .expect("stage lane");
        let fwd = track.events.iter().filter(|e| e.name == "fwd").count();
        assert_eq!(fwd, 2 * 2 * chunks, "2 steps x B/E x chunks");
    }

    // The export analyzes: one utilization row per stage over the two
    // steady windows, and the drift table prices the schedule.
    let path = tmp_file("real_pipeline");
    write_chrome_trace(&path, &a).expect("write trace");
    let analysis = analyze_file(&path).expect("analyze");
    assert_eq!(analysis.windows, 2);
    assert_eq!(analysis.stages.len(), stages);
    for row in &analysis.stages {
        assert_eq!(row.fwd_count, 2 * chunks);
        assert_eq!(row.bwd_count, 2 * chunks);
        assert!(row.util > 0.0);
    }
    assert!(!analysis.drift.is_empty());
    let _ = std::fs::remove_file(&path);
}
