//! Prep-path micro-benchmarks (Criterion-style statistics, no external
//! harness offline): the §7.2 host hot spots — `induce_subgraph`,
//! `EllGraph::from_graph`, `CooGraph::from_graph`,
//! `prepare_microbatches` (serial / parallel / pooled / cached) — with
//! mean ± stddev per iteration, dumped to `BENCH_prep.json` at the repo
//! root so future PRs have a perf trajectory to compare against.
//!
//! Run: `cargo bench --bench prep` (compile-checked in CI with
//! `cargo bench --no-run`).

use std::fmt::Write as _;
use std::time::Instant;

use gnn_pipe::batching::{Chunker, SequentialChunker};
use gnn_pipe::config::Config;
use gnn_pipe::data::generate;
use gnn_pipe::graph::{induce_subgraph, CooGraph, EllGraph};
use gnn_pipe::pipeline::{
    prepare_microbatches, prepare_microbatches_parallel, MicrobatchCache,
    MicrobatchPool,
};

struct Sample {
    name: String,
    iters: usize,
    mean_s: f64,
    std_s: f64,
    min_s: f64,
}

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Sample {
    f(); // warm-up
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
        / iters as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let s = Sample {
        name: name.to_string(),
        iters,
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: min,
    };
    let unit = |v: f64| {
        if v >= 1.0 {
            format!("{v:.3} s")
        } else if v >= 1e-3 {
            format!("{:.3} ms", v * 1e3)
        } else {
            format!("{:.3} us", v * 1e6)
        }
    };
    println!(
        "{name:<44} {:>12} ± {:>10}  (min {:>10}, {iters} iters)",
        unit(s.mean_s),
        unit(s.std_s),
        unit(s.min_s),
    );
    s
}

fn main() {
    let cfg = Config::load().expect("configs");
    let profile = cfg.dataset("pubmed").unwrap().clone();
    let ds = generate(&profile).unwrap();
    let g = &ds.graph;
    let chunks = 4usize;
    let plan = SequentialChunker.plan(g, chunks);
    let train_mask = ds.splits.train_mask(profile.nodes);
    let sub = induce_subgraph(g, &plan.chunks[0]);
    let e_cap = profile.chunk_e_cap(chunks);
    println!(
        "== prep microbench (pubmed-profile graph: {} nodes, {} edges, {chunks} chunks) ==",
        g.num_nodes(),
        g.num_edges()
    );

    let mut samples = Vec::new();
    samples.push(bench("induce_subgraph (1 chunk of 4)", 100, || {
        let _ = induce_subgraph(g, &plan.chunks[0]);
    }));
    samples.push(bench("EllGraph::from_graph (chunk sub-graph)", 100, || {
        let _ = EllGraph::from_graph(&sub.graph, profile.ell_k).unwrap();
    }));
    samples.push(bench("CooGraph::from_graph (chunk sub-graph)", 100, || {
        let _ = CooGraph::from_graph(&sub.graph, e_cap).unwrap();
    }));
    samples.push(bench("prepare_microbatches serial (paper)", 30, || {
        let _ = prepare_microbatches(&ds, &plan, "ell", &train_mask).unwrap();
    }));
    samples.push(bench("prepare_microbatches_parallel", 30, || {
        let _ =
            prepare_microbatches_parallel(&ds, &plan, "ell", &train_mask).unwrap();
    }));

    let mut pool = MicrobatchPool::new();
    pool.rebuild(&ds, &plan, "ell", &train_mask).unwrap();
    samples.push(bench("MicrobatchPool::rebuild (steady state)", 30, || {
        pool.rebuild(&ds, &plan, "ell", &train_mask).unwrap();
    }));

    let cache = MicrobatchCache::new();
    cache
        .get_or_build(&ds, &plan, "ell", &train_mask, None)
        .unwrap();
    samples.push(bench("MicrobatchCache hit", 1000, || {
        let _ = cache
            .get_or_build(&ds, &plan, "ell", &train_mask, None)
            .unwrap();
    }));

    // Snapshot for the perf trajectory: BENCH_prep.json at the repo root.
    let mut json = String::from("{\n  \"bench\": \"prep\",\n  \"dataset\": \"pubmed\",\n");
    let _ = writeln!(json, "  \"chunks\": {chunks},");
    json.push_str("  \"samples\": [\n");
    for (i, s) in samples.iter().enumerate() {
        let _ = write!(
            json,
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_s\": {:.9}, \"std_s\": {:.9}, \"min_s\": {:.9}}}",
            s.name, s.iters, s.mean_s, s.std_s, s.min_s
        );
        json.push_str(if i + 1 < samples.len() { ",\n" } else { "\n" });
    }
    json.push_str("  ]\n}\n");
    let path = cfg.root.join("BENCH_prep.json");
    std::fs::write(&path, json).expect("write BENCH_prep.json");
    println!("wrote {}", path.display());
}
