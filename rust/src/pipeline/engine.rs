//! The fill-drain execution engine: stage workers on OS threads,
//! micro-batches streaming through channels.
//!
//! Worker `s` owns the compiled executables of pipeline stage `s`
//! (fwd + rematerialising bwd) and processes micro-batches FIFO: the
//! forward wave runs 0→1→2→3 with stage `s` starting micro-batch `m`
//! as soon as `(m, s-1)` hands over — the GPipe overlap — then the
//! backward wave drains 3→2→1→0, accumulating parameter gradients
//! locally at the parameter-owning stages (0 and 2).
//!
//! Everything crossing a stage boundary is a `HostTensor` copy; on the
//! paper's DGX those copies are the NVLink/PCIe transfers, and the
//! device simulator prices them from the same shapes.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::{Engine, Executable, HostTensor};

use super::chunkprep::Microbatch;

/// Per-stage wall-clock accounting for one epoch.
#[derive(Debug, Clone, Default)]
pub struct StageTiming {
    /// Seconds inside the fwd executable, per micro-batch.
    pub fwd_s: Vec<f64>,
    /// Seconds inside the bwd executable(s), per micro-batch.
    pub bwd_s: Vec<f64>,
    /// Total busy seconds (fwd + bwd + local bookkeeping).
    pub busy_s: f64,
}

/// Result of one pipeline epoch (one optimiser step's worth of work).
#[derive(Debug)]
pub struct EpochOutput {
    /// Sum of masked NLL over all micro-batches.
    pub loss_sum: f64,
    /// Total mask count (normalisation for loss and grads).
    pub mask_count: f64,
    /// Gradients w.r.t. the loss SUM, in manifest param order.
    pub grads: Vec<HostTensor>,
    /// Per micro-batch: (original node ids, row-major log-probs).
    pub logp: Vec<(Vec<u32>, Vec<f32>)>,
    pub stage_timings: Vec<StageTiming>,
    pub wall_s: f64,
}

struct StageExecs {
    s0_fwd: Arc<Executable>,
    s1_fwd: Arc<Executable>,
    s2_fwd: Arc<Executable>,
    s3_fwd: Arc<Executable>,
    s3loss_bwd: Arc<Executable>,
    s2_bwd: Arc<Executable>,
    s1_bwd: Arc<Executable>,
    s0_bwd: Arc<Executable>,
}

/// A compiled pipeline for one (dataset, backend, chunk-count) triple.
pub struct PipelineEngine {
    execs: StageExecs,
    pub chunks: usize,
    pub backend: String,
    pub artifact_names: Vec<String>,
}

impl PipelineEngine {
    pub fn new(
        engine: &Engine,
        dataset: &str,
        backend: &str,
        chunks: usize,
    ) -> Result<PipelineEngine> {
        let name = |kind: &str| format!("{dataset}_{backend}_c{chunks}_{kind}");
        let kinds = [
            "s0_fwd", "s1_fwd", "s2_fwd", "s3_fwd", "s3loss_bwd", "s2_bwd",
            "s1_bwd", "s0_bwd",
        ];
        let artifact_names: Vec<String> = kinds.iter().map(|k| name(k)).collect();
        let get = |kind: &str| engine.executable(&name(kind));
        Ok(PipelineEngine {
            execs: StageExecs {
                s0_fwd: get("s0_fwd")?,
                s1_fwd: get("s1_fwd")?,
                s2_fwd: get("s2_fwd")?,
                s3_fwd: get("s3_fwd")?,
                s3loss_bwd: get("s3loss_bwd")?,
                s2_bwd: get("s2_bwd")?,
                s1_bwd: get("s1_bwd")?,
                s0_bwd: get("s0_bwd")?,
            },
            chunks,
            backend: backend.to_string(),
            artifact_names,
        })
    }

    /// Run one synchronous fill-drain pipeline step over the prepared
    /// micro-batches.
    ///
    /// `params` is the full flat parameter vector in manifest order
    /// (stage 0 takes [0..4], stage 2 takes [4..8]). `key` seeds the
    /// per-micro-batch dropout keys: micro-batch m uses
    /// (key.0 + m, key.1), so chunks=1 reproduces the monolithic
    /// train_step bit-for-bit (integration_pipeline.rs asserts this).
    pub fn run_epoch(
        &self,
        params: &[HostTensor],
        microbatches: &[Microbatch],
        key: (u32, u32),
    ) -> Result<EpochOutput> {
        anyhow::ensure!(params.len() == 8, "expected 8 flat params");
        let p1: Vec<HostTensor> = params[0..4].to_vec();
        let p2: Vec<HostTensor> = params[4..8].to_vec();
        let m_count = microbatches.len();
        anyhow::ensure!(m_count >= 1, "no micro-batches");
        let mbs: Arc<Vec<Microbatch>> = Arc::new(microbatches.to_vec());
        let keys: Vec<HostTensor> = (0..m_count)
            .map(|m| HostTensor::key(key.0.wrapping_add(m as u32), key.1))
            .collect();

        let wall = Instant::now();

        // Channels between adjacent stages (fwd ->, bwd <-).
        let (f01_tx, f01_rx) = mpsc::channel::<(usize, HostTensor)>();
        let (f12_tx, f12_rx) = mpsc::channel::<(usize, HostTensor)>();
        let (f23_tx, f23_rx) = mpsc::channel::<(usize, HostTensor)>();
        let (b32_tx, b32_rx) = mpsc::channel::<(usize, HostTensor)>();
        let (b21_tx, b21_rx) = mpsc::channel::<(usize, HostTensor)>();
        let (b10_tx, b10_rx) = mpsc::channel::<(usize, HostTensor)>();

        let e = &self.execs;
        let keys = Arc::new(keys);

        let result: Result<EpochOutput> = std::thread::scope(|scope| {
            // ---- worker 0: [Dropout, GAT1] --------------------------------
            let w0 = {
                let mbs = mbs.clone();
                let keys = keys.clone();
                let p1 = p1.clone();
                let (s0f, s0b) = (e.s0_fwd.clone(), e.s0_bwd.clone());
                scope.spawn(move || -> Result<(Vec<HostTensor>, StageTiming)> {
                    let mut t = StageTiming::default();
                    let busy = Instant::now();
                    for (m, mb) in mbs.iter().enumerate() {
                        let mut inp = p1.clone();
                        inp.push(mb.x.clone());
                        inp.extend(mb.graph.iter().cloned());
                        inp.push(keys[m].clone());
                        let t0 = Instant::now();
                        let out = s0f.run(&inp).context("s0_fwd")?;
                        t.fwd_s.push(t0.elapsed().as_secs_f64());
                        f01_tx.send((m, out.into_iter().next().unwrap())).ok();
                    }
                    // gradient accumulators for stage-0 params
                    let mut acc: Vec<HostTensor> =
                        p1.iter().map(|p| HostTensor::zeros_f32(p.shape().to_vec())).collect();
                    for _ in 0..mbs.len() {
                        let (m, dh0) = b10_rx.recv().context("b10 closed")?;
                        let mb = &mbs[m];
                        let mut inp = p1.clone();
                        inp.push(mb.x.clone());
                        inp.extend(mb.graph.iter().cloned());
                        inp.push(keys[m].clone());
                        inp.push(dh0);
                        let t0 = Instant::now();
                        let dps = s0b.run(&inp).context("s0_bwd")?;
                        t.bwd_s.push(t0.elapsed().as_secs_f64());
                        accumulate(&mut acc, &dps)?;
                    }
                    t.busy_s = busy.elapsed().as_secs_f64();
                    Ok((acc, t))
                })
            };

            // ---- worker 1: [ELU, Dropout] ---------------------------------
            let w1 = {
                let keys = keys.clone();
                let m_total = m_count;
                let (s1f, s1b) = (e.s1_fwd.clone(), e.s1_bwd.clone());
                scope.spawn(move || -> Result<StageTiming> {
                    let mut t = StageTiming::default();
                    let busy = Instant::now();
                    let mut stash: Vec<Option<HostTensor>> = vec![None; m_total];
                    for _ in 0..m_total {
                        let (m, h0) = f01_rx.recv().context("f01 closed")?;
                        let t0 = Instant::now();
                        let out = s1f.run(&[h0.clone(), keys[m].clone()]).context("s1_fwd")?;
                        t.fwd_s.push(t0.elapsed().as_secs_f64());
                        stash[m] = Some(h0);
                        f12_tx.send((m, out.into_iter().next().unwrap())).ok();
                    }
                    for _ in 0..m_total {
                        let (m, dh1) = b21_rx.recv().context("b21 closed")?;
                        let h0 = stash[m].take().context("missing stash")?;
                        let t0 = Instant::now();
                        let out = s1b.run(&[h0, keys[m].clone(), dh1]).context("s1_bwd")?;
                        t.bwd_s.push(t0.elapsed().as_secs_f64());
                        b10_tx.send((m, out.into_iter().next().unwrap())).ok();
                    }
                    t.busy_s = busy.elapsed().as_secs_f64();
                    Ok(t)
                })
            };

            // ---- worker 2: [GAT2] -----------------------------------------
            let w2 = {
                let mbs = mbs.clone();
                let keys = keys.clone();
                let p2 = p2.clone();
                let (s2f, s2b) = (e.s2_fwd.clone(), e.s2_bwd.clone());
                scope.spawn(move || -> Result<(Vec<HostTensor>, StageTiming)> {
                    let mut t = StageTiming::default();
                    let busy = Instant::now();
                    let mut stash: Vec<Option<HostTensor>> = vec![None; mbs.len()];
                    for _ in 0..mbs.len() {
                        let (m, h1) = f12_rx.recv().context("f12 closed")?;
                        let mb = &mbs[m];
                        let mut inp = p2.clone();
                        inp.push(h1.clone());
                        inp.extend(mb.graph.iter().cloned());
                        inp.push(keys[m].clone());
                        let t0 = Instant::now();
                        let out = s2f.run(&inp).context("s2_fwd")?;
                        t.fwd_s.push(t0.elapsed().as_secs_f64());
                        stash[m] = Some(h1);
                        f23_tx.send((m, out.into_iter().next().unwrap())).ok();
                    }
                    let mut acc: Vec<HostTensor> =
                        p2.iter().map(|p| HostTensor::zeros_f32(p.shape().to_vec())).collect();
                    for _ in 0..mbs.len() {
                        let (m, dlg) = b32_rx.recv().context("b32 closed")?;
                        let mb = &mbs[m];
                        let h1 = stash[m].take().context("missing stash")?;
                        let mut inp = p2.clone();
                        inp.push(h1);
                        inp.extend(mb.graph.iter().cloned());
                        inp.push(keys[m].clone());
                        inp.push(dlg);
                        let t0 = Instant::now();
                        let mut out = s2b.run(&inp).context("s2_bwd")?;
                        t.bwd_s.push(t0.elapsed().as_secs_f64());
                        let dh1 = out.pop().context("s2_bwd outputs")?;
                        accumulate(&mut acc, &out)?;
                        b21_tx.send((m, dh1)).ok();
                    }
                    t.busy_s = busy.elapsed().as_secs_f64();
                    Ok((acc, t))
                })
            };

            // ---- worker 3: [LogSoftmax + loss] ----------------------------
            let w3 = {
                let mbs = mbs.clone();
                let (s3f, s3b) = (e.s3_fwd.clone(), e.s3loss_bwd.clone());
                scope.spawn(move || -> Result<(f64, f64, Vec<(Vec<u32>, Vec<f32>)>, StageTiming)> {
                    let mut t = StageTiming::default();
                    let busy = Instant::now();
                    let mut loss_sum = 0.0f64;
                    let mut mask_count = 0.0f64;
                    let mut logps: Vec<(Vec<u32>, Vec<f32>)> =
                        vec![Default::default(); mbs.len()];
                    for _ in 0..mbs.len() {
                        let (m, lg) = f23_rx.recv().context("f23 closed")?;
                        let mb = &mbs[m];
                        let t0 = Instant::now();
                        let logp = s3f.run(&[lg.clone()]).context("s3_fwd")?;
                        t.fwd_s.push(t0.elapsed().as_secs_f64());
                        logps[m] =
                            (mb.nodes.clone(), logp[0].as_f32()?.to_vec());
                        // loss + dlogits (fused LogSoftmax+NLL backward)
                        let t1 = Instant::now();
                        let out = s3b
                            .run(&[lg, mb.labels.clone(), mb.mask.clone()])
                            .context("s3loss_bwd")?;
                        t.bwd_s.push(t1.elapsed().as_secs_f64());
                        loss_sum += out[0].scalar_value()? as f64;
                        mask_count += out[1].scalar_value()? as f64;
                        b32_tx.send((m, out[2].clone())).ok();
                    }
                    t.busy_s = busy.elapsed().as_secs_f64();
                    Ok((loss_sum, mask_count, logps, t))
                })
            };

            // Join everything, then report the most informative error: a
            // failing stage tears its channels down, so peers see "closed"
            // — the real failure is the one that does NOT mention a channel.
            let r0 = w0.join().expect("worker 0 panicked");
            let r1 = w1.join().expect("worker 1 panicked");
            let r2 = w2.join().expect("worker 2 panicked");
            let r3 = w3.join().expect("worker 3 panicked");
            let errs: Vec<String> = [
                r0.as_ref().err().map(|e| format!("{e:#}")),
                r1.as_ref().err().map(|e| format!("{e:#}")),
                r2.as_ref().err().map(|e| format!("{e:#}")),
                r3.as_ref().err().map(|e| format!("{e:#}")),
            ]
            .into_iter()
            .flatten()
            .collect();
            if !errs.is_empty() {
                let root = errs
                    .iter()
                    .find(|e| !e.contains("closed"))
                    .unwrap_or(&errs[0]);
                anyhow::bail!("pipeline stage failed: {root}");
            }
            let (acc1, t0s) = r0.unwrap();
            let t1s = r1.unwrap();
            let (acc2, t2s) = r2.unwrap();
            let (loss_sum, mask_count, logp, t3s) = r3.unwrap();

            let mut grads = acc1;
            grads.extend(acc2);
            Ok(EpochOutput {
                loss_sum,
                mask_count,
                grads,
                logp,
                stage_timings: vec![t0s, t1s, t2s, t3s],
                wall_s: wall.elapsed().as_secs_f64(),
            })
        });
        result
    }
}

/// acc += delta, elementwise, over parallel tensor lists.
fn accumulate(acc: &mut [HostTensor], delta: &[HostTensor]) -> Result<()> {
    anyhow::ensure!(acc.len() == delta.len(), "grad arity mismatch");
    for (a, d) in acc.iter_mut().zip(delta) {
        let d = d.as_f32()?;
        let a = a.as_f32_mut()?;
        anyhow::ensure!(a.len() == d.len(), "grad size mismatch");
        for (x, y) in a.iter_mut().zip(d) {
            *x += y;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_sums() {
        let mut acc = vec![HostTensor::zeros_f32(vec![3])];
        let d = vec![HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0])];
        accumulate(&mut acc, &d).unwrap();
        accumulate(&mut acc, &d).unwrap();
        assert_eq!(acc[0].as_f32().unwrap(), &[2.0, 4.0, 6.0]);
    }

    #[test]
    fn accumulate_rejects_mismatch() {
        let mut acc = vec![HostTensor::zeros_f32(vec![3])];
        let d = vec![HostTensor::zeros_f32(vec![4])];
        assert!(accumulate(&mut acc, &d).is_err());
    }
}
