//! Partitioner micro-benchmarks: the host-side cost of the balance DP,
//! the (stages, chunks, schedule) sweep, and the modeled-epoch pricing
//! it leans on — everything is closed-form, so this bench needs no
//! artifacts and always runs.
//!
//! Three sections:
//!
//! 1. **balance DP**: `balance_dp` on the pubmed closed-form profile
//!    across every (stages, chunks) point the CLI sweeps, plus a wider
//!    synthetic profile to exercise the DP's general path;
//! 2. **modeled epoch**: `model_epoch` replaying both schedules at the
//!    config's chunk counts;
//! 3. **full sweep**: `sweep` end to end — the exact search
//!    `gnn-pipe partition` runs — with the winner printed so drift in
//!    the chosen split is visible in bench logs.
//!
//! Mean ± stddev per iteration, dumped to `BENCH_partition.json` at the
//! repo root (CI's `bench-trajectory` job runs `-- --quick` and tracks
//! the snapshot per commit; the CLI `gnn-pipe bench partition` writes
//! the same file with `quick: false`).

mod bench_util;

use bench_util::{bench, quick_mode, scaled, write_snapshot};

use gnn_pipe::config::Config;
use gnn_pipe::pipeline::parse_schedule;
use gnn_pipe::pipeline::partition::{
    balance_dp, model_epoch, sweep, CostProfile, SweepConstraints,
};
use gnn_pipe::simulator::DEVICES;

fn main() {
    let quick = quick_mode();
    let iters = |n: usize| scaled(quick, n);
    let cfg = Config::load().expect("configs");
    println!(
        "== partition microbench (balance DP + sweep{}) ==",
        if quick { ", quick" } else { "" }
    );

    let profile = CostProfile::closed_form(
        &cfg.datasets["pubmed"],
        &cfg.model,
        &DEVICES.v100,
        &CostProfile::default_calibration(),
    );
    let devices = cfg.pipeline.devices;
    let chunk_counts = cfg.pipeline.chunks.clone();

    let mut samples = Vec::new();

    // 1a. The DP across the CLI's whole (stages, chunks) grid.
    samples.push(bench(
        &format!("balance_dp (stages 2..={devices} x chunks {chunk_counts:?})"),
        iters(2000),
        || {
            let mut acc = 0usize;
            for stages in 2..=devices.max(2) {
                for &chunks in &chunk_counts {
                    let part = balance_dp(&profile, stages, chunks).unwrap();
                    acc += part.cut_width;
                }
            }
            std::hint::black_box(acc);
        },
    ));

    // 1b. A wider uniform profile: stresses the DP's O(S * L^2) general
    // path rather than the 6-layer special case.
    let wide = CostProfile::uniform(6, 1e-3, 2e-3, 64);
    samples.push(bench("balance_dp (uniform profile, all stage counts)", iters(5000), || {
        let mut acc = 0.0f64;
        for stages in 1..=6 {
            acc += balance_dp(&wide, stages, 4).unwrap().bottleneck_s;
        }
        std::hint::black_box(acc);
    }));

    // 2. The modeled-epoch replay at every (chunks, schedule) point.
    let schedules: Vec<_> = ["fill-drain", "1f1b"]
        .iter()
        .map(|n| parse_schedule(n).unwrap())
        .collect();
    let canonical = balance_dp(&profile, devices, 1).unwrap();
    samples.push(bench(
        &format!("model_epoch (balance {:?} x 2 schedules)", canonical.balance),
        iters(2000),
        || {
            let mut acc = 0.0f64;
            for sched in &schedules {
                for &chunks in &chunk_counts {
                    let rep = model_epoch(
                        &profile,
                        &canonical.balance,
                        chunks,
                        sched.as_ref(),
                    )
                    .unwrap();
                    acc += rep.makespan_s;
                }
            }
            std::hint::black_box(acc);
        },
    ));

    // 3. The full search the `partition` subcommand runs.
    let cons = SweepConstraints::defaults(devices, &chunk_counts);
    let mut winner_desc = String::new();
    samples.push(bench(
        &format!(
            "sweep ({} stages x {} chunks x {} schedules)",
            cons.stages.len(),
            cons.chunks.len(),
            cons.schedules.len()
        ),
        iters(1000),
        || {
            let report = sweep(&profile, &cons).unwrap();
            let w = report.winner();
            winner_desc = format!(
                "{:?}/c{}/{}",
                w.balance, w.chunks, w.schedule
            );
        },
    ));
    println!("  (sweep winner: {winner_desc})");

    let extras = [
        ("quick", quick.to_string()),
        ("dp_balance", format!("\"{:?}\"", canonical.balance)),
        ("sweep_winner", format!("\"{winner_desc}\"")),
    ];
    write_snapshot(
        &cfg.root.join("BENCH_partition.json"),
        "partition",
        &extras,
        &samples,
    );
}
