//! Padded ELL device representation: fixed-width neighbour lists.
//!
//! Row i: slot 0 is the self-loop, then neighbours, zero-padded to K.
//! This is the rectangular, maskable layout the Pallas kernel consumes
//! (ARCHITECTURE.md §Hardware adaptation). Degree must be < K — the synthetic
//! generator guarantees it (degree cap), and `from_graph` enforces it.

use anyhow::Result;

use super::Graph;

#[derive(Debug, Clone, PartialEq)]
pub struct EllGraph {
    pub n: usize,
    pub k: usize,
    /// (n * k) neighbour ids, row-major; slot 0 of each row = self id.
    pub idx: Vec<i32>,
    /// (n * k) slot validity in {0.0, 1.0}.
    pub mask: Vec<f32>,
}

impl EllGraph {
    pub fn from_graph(g: &Graph, k: usize) -> Result<EllGraph> {
        let n = g.num_nodes();
        let mut idx = Vec::new();
        let mut mask = Vec::new();
        EllGraph::write_padded(g, k, n, &mut idx, &mut mask)?;
        Ok(EllGraph { n, k, idx, mask })
    }

    /// Export into caller buffers, zero-padded to `n_pad` rows — the
    /// single source of truth for the ELL layout. `from_graph` builds
    /// through this with `n_pad = n`; the micro-batch prep buffer pool
    /// refills its pooled `Vec`s through it (clear + resize, reusing the
    /// allocation).
    pub fn write_padded(
        g: &Graph,
        k: usize,
        n_pad: usize,
        idx: &mut Vec<i32>,
        mask: &mut Vec<f32>,
    ) -> Result<()> {
        let n = g.num_nodes();
        anyhow::ensure!(k >= 1, "ELL width must be >= 1");
        anyhow::ensure!(n <= n_pad, "{n} nodes > padded capacity {n_pad}");
        idx.clear();
        idx.resize(n_pad * k, 0);
        mask.clear();
        mask.resize(n_pad * k, 0.0);
        for v in 0..n {
            let nbrs = g.neighbors(v);
            anyhow::ensure!(
                nbrs.len() < k,
                "node {v} degree {} >= ELL width {k} (generator must cap degree)",
                nbrs.len()
            );
            let row = v * k;
            idx[row] = v as i32;
            mask[row] = 1.0;
            for (s, &j) in nbrs.iter().enumerate() {
                idx[row + 1 + s] = j as i32;
                mask[row + 1 + s] = 1.0;
            }
        }
        Ok(())
    }

    /// Count of valid non-self slots (directed edge endpoints present).
    pub fn directed_edges(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.0).count() - self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_and_masks() {
        let g = Graph::from_undirected_edges(3, &[(0, 1), (1, 2)]).unwrap();
        let e = g.to_ell(4).unwrap();
        // node 0: [0, 1, pad, pad]
        assert_eq!(&e.idx[0..4], &[0, 1, 0, 0]);
        assert_eq!(&e.mask[0..4], &[1.0, 1.0, 0.0, 0.0]);
        // node 1: [1, 0, 2, pad]
        assert_eq!(&e.idx[4..8], &[1, 0, 2, 0]);
        assert_eq!(&e.mask[4..8], &[1.0, 1.0, 1.0, 0.0]);
        assert_eq!(e.directed_edges(), 4);
    }

    #[test]
    fn rejects_over_capacity() {
        // star: center degree 4, needs k >= 5
        let g = Graph::from_undirected_edges(
            5,
            &[(0, 1), (0, 2), (0, 3), (0, 4)],
        )
        .unwrap();
        assert!(g.to_ell(4).is_err());
        assert!(g.to_ell(5).is_ok());
    }
}
