//! Synthetic citation datasets: the substitution for Cora/CiteSeer/PubMed.
//!
//! No network access exists in this environment, so the three citation
//! benchmarks are synthesised to their published statistics (node/edge/
//! feature/class counts from `configs/datasets.json`) by a
//! degree-capped, homophilous stochastic block model with
//! class-correlated sparse bag-of-words features (see `generator`).
//! ARCHITECTURE.md §Substitutions explains why this preserves the paper's
//! phenomena; `gnn-pipe data --dataset X` prints the realised statistics
//! next to the published targets.

mod generator;
mod sign;
mod splits;

pub use generator::{generate, GenerationReport};
pub use sign::sign_features;
pub use splits::Splits;

use crate::config::DatasetProfile;
use crate::graph::Graph;

/// A fully materialised dataset: host graph + features + labels + splits.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub profile: DatasetProfile,
    pub graph: Graph,
    /// Row-major (nodes x features), L1-row-normalised bag-of-words.
    pub features: Vec<f32>,
    pub labels: Vec<i32>,
    pub splits: Splits,
    pub report: GenerationReport,
}

impl Dataset {
    pub fn feature_row(&self, v: usize) -> &[f32] {
        let d = self.profile.features;
        &self.features[v * d..(v + 1) * d]
    }

    /// Gather feature rows for a node chunk, zero-padded to `n_pad` rows.
    pub fn gather_features(&self, nodes: &[u32], n_pad: usize) -> Vec<f32> {
        let d = self.profile.features;
        let mut out = vec![0f32; n_pad * d];
        for (i, &v) in nodes.iter().enumerate() {
            out[i * d..(i + 1) * d].copy_from_slice(self.feature_row(v as usize));
        }
        out
    }

    /// Gather labels for a node chunk, zero-padded (mask handles padding).
    pub fn gather_labels(&self, nodes: &[u32], n_pad: usize) -> Vec<i32> {
        let mut out = vec![0i32; n_pad];
        for (i, &v) in nodes.iter().enumerate() {
            out[i] = self.labels[v as usize];
        }
        out
    }

    /// Gather a 0/1 mask (train/val/test) for a node chunk, zero-padded.
    pub fn gather_mask(&self, mask: &[f32], nodes: &[u32], n_pad: usize) -> Vec<f32> {
        let mut out = vec![0f32; n_pad];
        for (i, &v) in nodes.iter().enumerate() {
            out[i] = mask[v as usize];
        }
        out
    }
}
