//! Chaos / failover invariants.
//!
//! Host-side tests (always run, no artifacts needed) pin the seeded
//! fault plans and the failover planner: plans are pure functions of
//! `(scenario, seed)`, and `plan_fleet_faults` conserves every request
//! (served or shed, never lost) across every scenario.
//!
//! End-to-end tests (skipped gracefully when `make artifacts` has not
//! run) pin the three acceptance contracts from the robustness issue:
//!
//! * **chaos determinism** — the same fault seed replays to a
//!   bit-identical fleet report: same failover plan, same served
//!   logits, same per-replica completion orders, same counters;
//! * **fault invariance** — a crash with survivors loses nothing: every
//!   request is still served, its logits bit-identical to the fused
//!   `full_eval` of the same nodes (and hence to the fault-free run) —
//!   rerouting changes *where* a request runs, never *what* it
//!   computes;
//! * **stall liveness** — a stage stall trips the link watchdog and
//!   surfaces as a replica error while the fleet fails the victim's
//!   requests over; it must never deadlock the run.

use std::time::{Duration, Instant};

use gnn_pipe::config::Config;
use gnn_pipe::data::generate;
use gnn_pipe::faults::{FaultPlan, FaultScenario};
use gnn_pipe::runtime::Engine;
use gnn_pipe::serve::{
    generate_trace, plan_fleet_faults, BatchPolicy, FleetPolicy, FleetSession,
    RouterKind, ServeSession, SloPolicy, TraceSpec, TrafficShape,
    DEFAULT_WATCHDOG_S,
};
use gnn_pipe::train::{flatten_params, init_params, Evaluator};

// ---------------------------------------------------------------------
// Host-side: plans and the failover planner.
// ---------------------------------------------------------------------

#[test]
fn chaos_plans_are_pure_functions_of_scenario_and_seed() {
    for &scenario in FaultScenario::all() {
        for seed in 0..32u64 {
            let a = FaultPlan::generate(scenario, seed, 4, 4, 512);
            let b = FaultPlan::generate(scenario, seed, 4, 4, 512);
            assert_eq!(a, b, "{scenario:?}/{seed} must replay bit-identically");
        }
    }
    // And the seed actually matters: crash points move across seeds.
    let distinct: std::collections::HashSet<String> = (0..32u64)
        .map(|s| {
            format!("{:?}", FaultPlan::generate(FaultScenario::Crash, s, 4, 4, 512).events)
        })
        .collect();
    assert!(distinct.len() > 1, "crash plans must vary with the seed");
}

#[test]
fn failover_planner_conserves_every_request_across_scenarios() {
    let policy = BatchPolicy { max_batch: 8, max_wait_s: 0.02 };
    for &scenario in FaultScenario::all() {
        for replicas in [2usize, 3, 4] {
            let fleet = FleetPolicy {
                replicas,
                router: RouterKind::Jsq,
                slo: Some(SloPolicy { p99_target_s: 0.2, max_defer_s: 0.08 }),
                service_model_s: 0.02,
            };
            let trace = generate_trace(
                &TraceSpec { rate_hz: 150.0, requests: 900, seed: 13 },
                TrafficShape::Poisson,
                500,
            );
            let plan = FaultPlan::generate(scenario, 7, replicas, 4, trace.len());
            let a = plan_fleet_faults(&trace, &policy, &fleet, Some(&plan), 10.0);
            let b = plan_fleet_faults(&trace, &policy, &fleet, Some(&plan), 10.0);
            assert_eq!(a, b, "{scenario:?}/R={replicas}: planner must be pure");
            assert_eq!(
                a.plan.served + a.plan.shed,
                trace.len(),
                "{scenario:?}/R={replicas}: every request served or shed"
            );
            // Orphans split exactly into failover + brown-out sheds.
            let base_subs = a.base.sub_traces(&trace, replicas);
            let orphans: usize = (0..replicas)
                .map(|r| match (a.doomed[r], a.crashed[r]) {
                    (true, _) => base_subs[r].len(),
                    (false, Some(k)) => base_subs[r].len().saturating_sub(k),
                    (false, None) => 0,
                })
                .sum();
            assert_eq!(
                a.failover + a.degraded,
                orphans,
                "{scenario:?}/R={replicas}: orphans must be rerouted or shed"
            );
        }
    }
}

// ---------------------------------------------------------------------
// End-to-end (artifact-gated).
// ---------------------------------------------------------------------

fn engine() -> Option<(Config, Engine)> {
    let cfg = Config::load().ok()?;
    if !cfg.artifacts_dir().join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    let eng = Engine::from_artifacts_dir(&cfg.artifacts_dir()).ok()?;
    if !ServeSession::artifacts_available(&eng, &cfg.pipeline.pipeline_dataset, "ell") {
        eprintln!("skipping: serving artifacts missing; re-run `make artifacts`");
        return None;
    }
    Some((cfg, eng))
}

#[test]
fn chaos_replay_is_bit_identical() {
    let Some((cfg, eng)) = engine() else { return };
    let profile = cfg.dataset(&cfg.pipeline.pipeline_dataset).unwrap();
    let ds = generate(profile).unwrap();
    let params = flatten_params(
        &init_params(profile, &cfg.model, 7),
        &eng.manifest.param_order,
    )
    .unwrap();
    let trace = generate_trace(
        &TraceSpec { rate_hz: 64.0, requests: 36, seed: 5 },
        TrafficShape::Poisson,
        profile.nodes,
    );
    let policy = BatchPolicy { max_batch: 6, max_wait_s: 0.05 };
    let fleet = FleetPolicy {
        replicas: 3,
        router: RouterKind::Jsq,
        slo: None,
        service_model_s: 0.02,
    };
    // Chaos = crash + slow + flaky: exercises failover, the injected
    // per-batch delay, and the bounded transient-retry path at once.
    let chaos = FaultPlan::generate(FaultScenario::Chaos, 11, 3, 4, trace.len());
    let session = FleetSession::new(&eng, &ds, "ell");
    let a = session
        .run_with_faults(&params, &trace, &policy, &fleet, Some(&chaos))
        .unwrap();
    let b = session
        .run_with_faults(&params, &trace, &policy, &fleet, Some(&chaos))
        .unwrap();
    assert_eq!(a.fault_plan, b.fault_plan, "failover plan must be pure");
    assert_eq!(
        a.request_logits, b.request_logits,
        "served logits must be bit-identical across chaos replays"
    );
    assert_eq!(a.replica_orders, b.replica_orders);
    assert_eq!(a.report.served, b.report.served);
    assert_eq!(a.report.failover, b.report.failover);
    assert_eq!(a.report.degraded, b.report.degraded);
    assert_eq!(a.report.retries, b.report.retries);
    assert_eq!(a.report.failed, b.report.failed);
    assert_eq!(a.report.replica_errors, b.report.replica_errors);
    assert_eq!(a.report.failed, 0, "bounded retries must absorb transients");
}

#[test]
fn flaky_transients_are_retried_not_fatal() {
    let Some((cfg, eng)) = engine() else { return };
    let profile = cfg.dataset(&cfg.pipeline.pipeline_dataset).unwrap();
    let ds = generate(profile).unwrap();
    let params = flatten_params(
        &init_params(profile, &cfg.model, 7),
        &eng.manifest.param_order,
    )
    .unwrap();
    // R=2, 36 requests: replica 0 (the stage-fault target) owns ~3
    // batches, so an injected transient at micro-batch 0 or 1 is
    // guaranteed to fire.
    let trace = generate_trace(
        &TraceSpec { rate_hz: 64.0, requests: 36, seed: 5 },
        TrafficShape::Poisson,
        profile.nodes,
    );
    let policy = BatchPolicy { max_batch: 6, max_wait_s: 0.05 };
    let fleet = FleetPolicy {
        replicas: 2,
        router: RouterKind::Jsq,
        slo: None,
        service_model_s: 0.02,
    };
    let flaky = FaultPlan::generate(FaultScenario::Flaky, 7, 2, 4, trace.len());
    let session = FleetSession::new(&eng, &ds, "ell");
    let out = session
        .run_with_faults(&params, &trace, &policy, &fleet, Some(&flaky))
        .unwrap();
    assert!(out.report.retries > 0, "injected transients must force retries");
    assert_eq!(out.report.failed, 0, "bounded retries must absorb transients");
    assert_eq!(out.report.served, trace.len());
    assert!(
        out.report.replica_errors.iter().all(Option::is_none),
        "absorbed transients must not surface as replica errors: {:?}",
        out.report.replica_errors
    );
}

#[test]
fn crash_with_survivors_loses_nothing_and_matches_full_eval() {
    let Some((cfg, eng)) = engine() else { return };
    let profile = cfg.dataset(&cfg.pipeline.pipeline_dataset).unwrap();
    let ds = generate(profile).unwrap();
    let params_map = init_params(profile, &cfg.model, 3);
    let params =
        flatten_params(&params_map, &eng.manifest.param_order).unwrap();
    let trace = generate_trace(
        &TraceSpec { rate_hz: 64.0, requests: 36, seed: 11 },
        TrafficShape::Poisson,
        profile.nodes,
    );
    let policy = BatchPolicy { max_batch: 6, max_wait_s: 0.05 };
    let fleet = FleetPolicy {
        replicas: 3,
        router: RouterKind::Jsq,
        slo: None,
        service_model_s: 0.025,
    };
    let crash = FaultPlan::generate(FaultScenario::Crash, 7, 3, 4, trace.len());
    let session = FleetSession::new(&eng, &ds, "ell");
    let faulted = session
        .run_with_faults(&params, &trace, &policy, &fleet, Some(&crash))
        .unwrap();
    // Ungated (no SLO) with two survivors: the whole orphaned suffix
    // fails over and every request is still served.
    assert!(faulted.report.failover > 0, "the crash must orphan a suffix");
    assert_eq!(faulted.report.served, trace.len());
    assert_eq!(faulted.report.shed, 0);
    assert_eq!(faulted.report.failed, 0);
    assert!(
        faulted.report.replica_errors.iter().all(Option::is_none),
        "a planned crash is not an execution error: {:?}",
        faulted.report.replica_errors
    );
    // Fault invariance, both ways: bit-equal to the fault-free fleet
    // run and to the fused full-graph evaluation.
    let clean = session.run(&params, &trace, &policy, &fleet).unwrap();
    assert_eq!(
        faulted.request_logits, clean.request_logits,
        "failover must not change any served logit"
    );
    let evaluator = Evaluator::new(&eng, &ds, "ell").unwrap();
    let logp = evaluator.log_probs(&params_map).unwrap();
    let c = profile.classes;
    for (i, r) in trace.iter().enumerate() {
        let want = &logp[r.node as usize * c..(r.node as usize + 1) * c];
        assert_eq!(
            faulted.request_logits[i].as_slice(),
            want,
            "request {i} (node {}) diverges from full_eval after failover",
            r.node
        );
    }
}

#[test]
fn stall_trips_the_watchdog_instead_of_deadlocking() {
    // Gate on artifacts first (cheap), then run the whole session on a
    // detached worker that owns its own engine: the stalled stage
    // sleeps 30-60s in interruptible slices, so the main thread holds
    // the run to a hard deadline via `recv_timeout` — a deadlock fails
    // the test instead of hanging it.
    if engine().is_none() {
        return;
    }
    let stall = FaultPlan::generate(FaultScenario::Stall, 3, 2, 4, 24);
    assert!(
        stall.stall_doom(0.25).is_some(),
        "generated stalls (30-60s) must doom a 0.25s watchdog"
    );
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let (cfg, eng) = engine().expect("artifacts vanished mid-test");
        let profile = cfg.dataset(&cfg.pipeline.pipeline_dataset).unwrap();
        let ds = generate(profile).unwrap();
        let params = flatten_params(
            &init_params(profile, &cfg.model, 7),
            &eng.manifest.param_order,
        )
        .unwrap();
        let trace = generate_trace(
            &TraceSpec { rate_hz: 64.0, requests: 24, seed: 5 },
            TrafficShape::Poisson,
            profile.nodes,
        );
        let policy = BatchPolicy { max_batch: 6, max_wait_s: 0.05 };
        let fleet = FleetPolicy {
            replicas: 2,
            router: RouterKind::Jsq,
            slo: None,
            service_model_s: 0.02,
        };
        let mut session = FleetSession::new(&eng, &ds, "ell");
        session.set_watchdog_s(0.25);
        assert!(session.watchdog_s() < DEFAULT_WATCHDOG_S);
        let out = session
            .run_with_faults(&params, &trace, &policy, &fleet, Some(&stall))
            .unwrap();
        let _ = tx.send((out.report, trace.len()));
    });
    // Far below the 30s stall floor: the watchdog (0.25s) must resolve
    // the doomed replica long before the sleeper would wake on its own.
    let started = Instant::now();
    let (report, requests) = match rx.recv_timeout(Duration::from_secs(120)) {
        Ok(v) => v,
        Err(e) => panic!(
            "stalled fleet run did not resolve within {:?} ({e}): the \
             watchdog failed to break the deadlock",
            started.elapsed()
        ),
    };
    // The doomed replica's timeout is recorded, not fatal: its whole
    // sub-trace failed over to the survivor and everything was served.
    let timeout_err = report
        .replica_errors
        .iter()
        .flatten()
        .find(|e| e.contains("timed out"));
    assert!(
        timeout_err.is_some(),
        "the stalled replica must surface a StageTimeout: {:?}",
        report.replica_errors
    );
    assert_eq!(report.served, requests);
    assert_eq!(report.failed, 0, "a doomed replica is planned, not failed");
    assert!(report.failover > 0, "the doomed sub-trace must fail over");
}
