#!/usr/bin/env python3
"""Compare BENCH_*.json perf-trajectory snapshots against a previous run.

Usage: bench_diff.py [PREV_DIR] [NEW_DIR] [--threshold PCT] [--strict]
       bench_diff.py --selfcheck

Matches snapshots by filename and samples by name, prints a per-sample
delta table, and emits GitHub Actions `::warning::` annotations for any
sample whose mean regressed by more than --threshold percent (default
20). Samples present on only one side (added/renamed/removed benches)
are listed but never flagged. Exit code is 0 unless --strict is given
and at least one regression was found.

This is the first consumer of the bench-trajectory artifacts CI has
been uploading per commit: the previous run's BENCH_*.json land in
PREV_DIR (downloaded from the last successful run on the default
branch) and the current run's in NEW_DIR (the repo root). A missing or
empty PREV_DIR — the first run ever, or the first run after a new
snapshot such as BENCH_serve.json appears — compares nothing and exits
0. `--selfcheck` exercises exactly those paths (pytest-free; CI runs it
before the real comparison).
"""

import argparse
import json
import sys
from pathlib import Path


def load_snapshots(directory: Path, exclude: Path | None = None):
    """({filename: {sample_name: mean_s}}, {unreadable filenames}) for
    every BENCH_*.json below `directory` (artifact downloads sometimes
    nest one level). Unreadable, truncated, or non-object files go into
    the second set with a warning instead of crashing — a corrupt
    *baseline* must degrade to "first run", never fail the trajectory
    job. Paths under `exclude` are skipped — in CI the new dir is the
    repo root, which CONTAINS the downloaded previous artifact; without
    the exclusion the previous snapshots shadow the fresh ones and the
    comparison degenerates to prev-vs-prev."""
    out = {}
    unreadable = set()
    exclude = exclude.resolve() if exclude else None
    for path in sorted(directory.rglob("BENCH_*.json")):
        if exclude and exclude in path.resolve().parents:
            continue
        try:
            data = json.loads(path.read_text())
            if not isinstance(data, dict):
                raise ValueError(f"expected a JSON object, got {type(data).__name__}")
        except (OSError, ValueError) as e:  # JSONDecodeError is a ValueError
            print(f"::warning::unreadable snapshot {path}: {e}")
            unreadable.add(path.name)
            continue
        samples = {
            s["name"]: float(s["mean_s"])
            for s in data.get("samples", [])
            if "name" in s and "mean_s" in s
        }
        out[path.name] = {"samples": samples, "quick": data.get("quick")}
    return out, unreadable


def fmt_secs(v: float) -> str:
    if v >= 1.0:
        return f"{v:.3f} s"
    if v >= 1e-3:
        return f"{v * 1e3:.3f} ms"
    return f"{v * 1e6:.3f} us"


def compare(prev_dir: Path, new_dir: Path, threshold: float, strict: bool) -> int:
    """The whole diff as a callable (main() is argv plumbing; the
    self-check drives this directly). Absent baselines are a feature,
    not an error: the first run of a new repo — or of a new snapshot
    like BENCH_serve.json — has nothing to compare against and must
    exit 0 quietly so CI's trajectory job never fails on day one."""
    if not prev_dir.is_dir():
        print(f"no previous bench artifact at {prev_dir}; nothing to compare")
        return 0
    if not new_dir.is_dir():
        print(f"::warning::new-run directory {new_dir} does not exist")
        return 0
    prev, prev_bad = load_snapshots(prev_dir)
    new, _ = load_snapshots(new_dir, exclude=prev_dir)
    if not prev:
        print(f"no readable BENCH_*.json under {prev_dir}; nothing to compare")
        return 0
    if not new:
        print(f"::warning::no BENCH_*.json under {new_dir} to compare")
        return 0

    regressions = 0
    for fname, new_snap in sorted(new.items()):
        prev_snap = prev.get(fname)
        if prev_snap is None:
            if fname in prev_bad:
                print(f"{fname}: previous snapshot unreadable — "
                      "treating as first run")
            else:
                print(f"{fname}: new snapshot (no previous artifact) — skipped")
            continue
        if prev_snap.get("quick") != new_snap.get("quick"):
            print(f"{fname}: quick-mode mismatch vs previous — skipped")
            continue
        print(f"\n== {fname} (threshold {threshold:.0f}%) ==")
        for name, new_mean in new_snap["samples"].items():
            old_mean = prev_snap["samples"].get(name)
            if old_mean is None:
                print(f"  {name:<48} {fmt_secs(new_mean):>12}  (new sample)")
                continue
            delta = (new_mean - old_mean) / old_mean * 100.0 if old_mean > 0 else 0.0
            marker = ""
            if delta > threshold:
                marker = "  <-- REGRESSION"
                regressions += 1
                print(f"::warning::perf regression in {fname} / {name}: "
                      f"{fmt_secs(old_mean)} -> {fmt_secs(new_mean)} ({delta:+.1f}%)")
            print(f"  {name:<48} {fmt_secs(old_mean):>12} -> {fmt_secs(new_mean):>12}"
                  f"  ({delta:+6.1f}%){marker}")
        for name in prev_snap["samples"]:
            if name not in new_snap["samples"]:
                print(f"  {name:<48} (removed)")

    if regressions:
        print(f"\n{regressions} sample(s) regressed beyond {threshold:.0f}%")
        return 1 if strict else 0
    print("\nno regressions beyond threshold")
    return 0


def _snapshot(samples: dict, quick: bool = True, **extras) -> str:
    return json.dumps({
        "bench": "x",
        "quick": quick,
        **extras,
        "samples": [{"name": n, "iters": 1, "mean_s": m, "std_s": 0.0,
                     "min_s": m} for n, m in samples.items()],
    })


def selfcheck() -> int:
    """Exercise the absent-baseline and mismatch paths end to end in a
    temp dir (no pytest dependency — CI calls `bench_diff.py
    --selfcheck` directly). Asserts on exit codes; prints PASS/FAIL."""
    import contextlib
    import io
    import tempfile

    failures = []

    def case(name, expect_code, prev_setup, new_setup,
             threshold=20.0, strict=False, expect_text=None):
        with tempfile.TemporaryDirectory() as td:
            root = Path(td)
            prev, new = root / "prev", root / "new"
            prev_setup(prev)
            new_setup(new)
            buf = io.StringIO()
            with contextlib.redirect_stdout(buf):
                code = compare(prev, new, threshold, strict)
            ok = code == expect_code and (
                expect_text is None or expect_text in buf.getvalue())
            print(f"  [{'PASS' if ok else 'FAIL'}] {name} (exit {code})")
            if not ok:
                failures.append(name)
                print(buf.getvalue())

    def absent(path: Path):
        pass

    def empty(path: Path):
        path.mkdir()

    def snaps(**files):
        def setup(path: Path):
            path.mkdir()
            for fname, text in files.items():
                (path / fname).write_text(text)
        return setup

    base = _snapshot({"a": 1.0, "b": 2.0})
    print("bench_diff self-check:")
    case("missing previous dir exits 0", 0, absent,
         snaps(**{"BENCH_x.json": base}), expect_text="no previous bench")
    case("empty previous dir exits 0", 0, empty,
         snaps(**{"BENCH_x.json": base}), expect_text="nothing to compare")
    case("missing new dir exits 0", 0, snaps(**{"BENCH_x.json": base}),
         absent)
    case("new snapshot file (first BENCH_serve.json) is skipped", 0,
         snaps(**{"BENCH_x.json": base}),
         snaps(**{"BENCH_x.json": base, "BENCH_serve.json": base}),
         expect_text="BENCH_serve.json: new snapshot")
    case("clean diff exits 0", 0, snaps(**{"BENCH_x.json": base}),
         snaps(**{"BENCH_x.json": base}), expect_text="no regressions")
    case("regression without --strict exits 0", 0,
         snaps(**{"BENCH_x.json": base}),
         snaps(**{"BENCH_x.json": _snapshot({"a": 10.0, "b": 2.0})}),
         expect_text="REGRESSION")
    case("regression with --strict exits 1", 1,
         snaps(**{"BENCH_x.json": base}),
         snaps(**{"BENCH_x.json": _snapshot({"a": 10.0, "b": 2.0})}),
         strict=True)
    case("quick-mode mismatch is skipped", 0,
         snaps(**{"BENCH_x.json": _snapshot({"a": 1.0}, quick=False)}),
         snaps(**{"BENCH_x.json": base}), strict=True,
         expect_text="quick-mode mismatch")
    case("unreadable snapshot warns instead of crashing", 0,
         snaps(**{"BENCH_x.json": "{not json"}),
         snaps(**{"BENCH_x.json": base}), expect_text="unreadable snapshot")
    # The fleet snapshot's first appearance: no previous BENCH_fleet.json
    # artifact exists, so it must be skipped, never flagged — even strict.
    fleet = _snapshot({"plan_fleet (100k requests, R=4, SLO gate)": 0.01,
                       "cli fleet shed rate (R=4,poisson,rate=64)": 0.12},
                      shed_rate=0.12)
    case("first-run BENCH_fleet.json is skipped", 0,
         snaps(**{"BENCH_x.json": base}),
         snaps(**{"BENCH_x.json": base, "BENCH_fleet.json": fleet}),
         strict=True, expect_text="BENCH_fleet.json: new snapshot")
    # New fleet metrics (e.g. a shed-rate column joining an existing
    # snapshot) are informational on first appearance: a '(new sample)'
    # line, no regression flag, exit 0 even strict with an absurd mean.
    fleet_plus_shed = _snapshot(
        {"plan_fleet (100k requests, R=4, SLO gate)": 0.01,
         "cli fleet shed rate (R=4,poisson,rate=64)": 1e9},
        shed_rate=0.99)
    case("new shed-rate sample is informational, not a regression", 0,
         snaps(**{"BENCH_fleet.json": _snapshot(
             {"plan_fleet (100k requests, R=4, SLO gate)": 0.01})}),
         snaps(**{"BENCH_fleet.json": fleet_plus_shed}),
         strict=True, expect_text="(new sample)")
    # The faults snapshot's first appearance (PR adding the chaos bench):
    # no previous BENCH_faults.json artifact exists, so it is skipped,
    # never flagged — even strict.
    faults = _snapshot(
        {"plan_fleet_faults (100k requests, R=4, crash)": 0.02,
         "fleet_availability model (1k points)": 0.001},
        model_completion=0.76)
    case("first-run BENCH_faults.json is skipped", 0,
         snaps(**{"BENCH_x.json": base}),
         snaps(**{"BENCH_x.json": base, "BENCH_faults.json": faults}),
         strict=True, expect_text="BENCH_faults.json: new snapshot")
    # Availability metrics (completion fractions recorded as mean_s
    # pseudo-samples by `bench serve-faults`) joining an existing faults
    # snapshot are informational on first appearance, not regressions —
    # a completion of 0.97 must not diff against a planner timing.
    faults_plus_avail = _snapshot(
        {"plan_fleet_faults (100k requests, R=4, crash)": 0.02,
         "cli faults completion (crash,R=3)": 0.97,
         "cli faults model completion (crash,R=3)": 0.94},
        model_completion=0.94)
    case("new availability-metric sample is informational", 0,
         snaps(**{"BENCH_faults.json": faults}),
         snaps(**{"BENCH_faults.json": faults_plus_avail}),
         strict=True, expect_text="(new sample)")
    # The partition snapshot's first appearance (PR adding the
    # auto-partitioner): no previous BENCH_partition.json artifact
    # exists, so it is skipped, never flagged — even strict.
    partition = _snapshot(
        {"balance_dp (stages 2..=4 x chunks [1, 2, 3, 4])": 0.0004,
         "sweep (3 stages x 4 chunks x 2 schedules)": 0.003},
        sweep_winner="\"[2, 2, 1, 1]/c4/1f1b\"")
    case("first-run BENCH_partition.json is skipped", 0,
         snaps(**{"BENCH_x.json": base}),
         snaps(**{"BENCH_x.json": base, "BENCH_partition.json": partition}),
         strict=True, expect_text="BENCH_partition.json: new snapshot")
    # A corrupt or truncated *baseline* snapshot (interrupted artifact
    # download, pre-atomic-write crash) must degrade to "first run":
    # warn, skip that one file, keep diffing the others, exit 0 even
    # under --strict with a would-be regression in the new side.
    case("truncated baseline degrades to first run", 0,
         snaps(**{"BENCH_x.json": base, "BENCH_y.json": base[:17]}),
         snaps(**{"BENCH_x.json": base,
                  "BENCH_y.json": _snapshot({"a": 10.0, "b": 2.0})}),
         strict=True, expect_text="treating as first run")
    case("non-object baseline JSON degrades to first run", 0,
         snaps(**{"BENCH_x.json": base, "BENCH_y.json": "[1, 2, 3]"}),
         snaps(**{"BENCH_x.json": base,
                  "BENCH_y.json": _snapshot({"a": 10.0})}),
         strict=True, expect_text="treating as first run")
    case("all baselines corrupt still exits 0", 0,
         snaps(**{"BENCH_x.json": "{not json"}),
         snaps(**{"BENCH_x.json": base}), strict=True,
         expect_text="no readable BENCH_*.json")
    # The parameter-store snapshot's first appearance (PR adding the
    # versioned store + canary rollout): no previous BENCH_params.json
    # artifact exists, so it is skipped, never flagged — even strict.
    params = _snapshot(
        {"cli canary base p99 (canary-25)": 0.05,
         "cli canary candidate p99 (canary-25)": 0.06,
         "cli canary base p99 (gate-trip)": 0.05},
        source="bench serve-canary")
    case("first-run BENCH_params.json is skipped", 0,
         snaps(**{"BENCH_x.json": base}),
         snaps(**{"BENCH_x.json": base, "BENCH_params.json": params}),
         strict=True, expect_text="BENCH_params.json: new snapshot")
    # The trace snapshot's first appearance (PR adding the tracing
    # subsystem + overhead bench): no previous BENCH_trace.json artifact
    # exists, so it is skipped, never flagged — even strict.
    trace = _snapshot(
        {"record+drain 10k spans + 10k instants": 0.002,
         "synthetic epoch (trace disabled)": 0.006,
         "synthetic epoch (instrumented)": 0.0061},
        overhead_pct="1.7")
    case("first-run BENCH_trace.json is skipped", 0,
         snaps(**{"BENCH_x.json": base}),
         snaps(**{"BENCH_x.json": base, "BENCH_trace.json": trace}),
         strict=True, expect_text="BENCH_trace.json: new snapshot")

    if failures:
        print(f"self-check FAILED: {failures}")
        return 1
    print("self-check OK")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("prev_dir", type=Path, nargs="?", default=Path("prev-bench"))
    ap.add_argument("new_dir", type=Path, nargs="?", default=Path("."))
    ap.add_argument("--threshold", type=float, default=20.0,
                    help="regression threshold in percent (default 20)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 when a regression exceeds the threshold")
    ap.add_argument("--selfcheck", action="store_true",
                    help="run the built-in behavioural checks and exit")
    args = ap.parse_args()
    if args.selfcheck:
        return selfcheck()
    return compare(args.prev_dir, args.new_dir, args.threshold, args.strict)


if __name__ == "__main__":
    sys.exit(main())
