//! E14 — auto-partitioning: the hand-authored gat4 split vs the DP
//! balancer vs the full (stages, chunks, schedule) sweep winner, at
//! every chunk count the config sweeps.
//!
//! Modeled columns price each balance with `partition::model_epoch`
//! (closed-form roofline profile, the session's schedule); measured
//! columns are real pipeline training epochs — only available for the
//! canonical balance, because non-canonical splits need span artifacts
//! (`aot.py --partition`) that the default artifact dir doesn't carry.
//! The DP row must never model WORSE than the hand-authored row (it
//! searches a superset containing that split); the bench prints the
//! check explicitly per chunk count.
//!
//! Emits `partition.csv` and a `BENCH_partition.json` snapshot (CLI
//! writer: `quick: false`; CI's trajectory job uses
//! `benches/partition.rs` — same dual-writer convention as
//! `BENCH_faults.json`).

use std::fmt::Write as _;

use anyhow::Result;

use crate::metrics::{write_bench_snapshot, BenchSample, Table};
use crate::pipeline::partition::{
    balance_dp, model_epoch, sweep, CostProfile, SweepConstraints,
    CANONICAL_BALANCE,
};
use crate::simulator::DEVICES;

use super::{framework_label, BenchCtx};

/// E14: hand-authored gat4 vs DP-balanced vs sweep winner — modeled
/// epochs per chunk count, measured where artifacts exist.
pub fn bench_partition(ctx: &BenchCtx) -> Result<String> {
    let ds_name = ctx.cfg.pipeline.pipeline_dataset.clone();
    let backend = ctx
        .cfg
        .pipeline
        .pipeline_backends
        .first()
        .cloned()
        .unwrap_or_else(|| "ell".to_string());
    let devices = ctx.cfg.pipeline.devices;
    let chunk_counts = ctx.cfg.pipeline.chunks.clone();
    let profile = CostProfile::closed_form(
        ctx.cfg.dataset(&ds_name)?,
        &ctx.cfg.model,
        &DEVICES.v100,
        &CostProfile::default_calibration(),
    );

    let mut table = Table::new(&[
        "Chunks",
        "Split",
        "Balance",
        "Modeled epoch",
        "Measured epoch",
        "Bottleneck",
        "Bubble",
    ]);
    let mut csv = String::from(
        "chunks,split,balance,schedule,modeled_epoch_s,measured_epoch_s,\
         bottleneck_s,bubble_fraction\n",
    );
    let mut snapshot: Vec<BenchSample> = Vec::new();
    let mut point = |name: String, mean_s: f64| {
        snapshot.push(BenchSample {
            name,
            iters: 1,
            mean_s,
            std_s: 0.0,
            min_s: mean_s,
        });
    };
    let mut dp_never_worse = true;

    for &chunks in &chunk_counts {
        // Measured epochs exist only for the canonical split — the
        // artifact dir carries the gat4 stage kinds.
        let measured = match ctx.pipeline_run(&backend, chunks, false, false) {
            Ok(run) => Some(run.timing.avg_epoch_s()),
            Err(e) => {
                eprintln!(
                    "[bench] partition: measured run (chunks={chunks}) \
                     unavailable: {e:#}"
                );
                None
            }
        };
        let fmt_measured = |canonical: bool| match (canonical, measured) {
            (true, Some(s)) => format!("{:.4} s", s),
            _ => "-".to_string(),
        };

        let hand = model_epoch(
            &profile,
            &CANONICAL_BALANCE,
            chunks,
            ctx.schedule.as_ref(),
        )?;
        let part = balance_dp(&profile, devices, chunks)?;
        let dp = model_epoch(
            &profile,
            &part.balance,
            chunks,
            ctx.schedule.as_ref(),
        )?;
        let dp_is_canonical = part.balance[..] == CANONICAL_BALANCE;
        dp_never_worse &= dp.makespan_s <= hand.makespan_s + 1e-12;

        for (split, balance, rep, canonical, bottleneck) in [
            (
                "gat4",
                CANONICAL_BALANCE.to_vec(),
                &hand,
                true,
                f64::NAN,
            ),
            ("dp", part.balance.clone(), &dp, dp_is_canonical, part.bottleneck_s),
        ] {
            table.row(&[
                format!("{chunks}"),
                split.to_string(),
                format!("{balance:?}"),
                format!("{:.4e} s", rep.makespan_s),
                fmt_measured(canonical),
                if bottleneck.is_nan() {
                    "-".to_string()
                } else {
                    format!("{bottleneck:.4e} s")
                },
                format!("{:.3}", rep.bubble_fraction),
            ]);
            let _ = writeln!(
                csv,
                "{chunks},{split},\"{balance:?}\",{},{:.6e},{},{},{:.4}",
                ctx.schedule.name(),
                rep.makespan_s,
                match (canonical, measured) {
                    (true, Some(s)) => format!("{s:.6e}"),
                    _ => String::new(),
                },
                if bottleneck.is_nan() {
                    String::new()
                } else {
                    format!("{bottleneck:.6e}")
                },
                rep.bubble_fraction,
            );
            point(
                format!("cli partition {split} modeled epoch (c={chunks})"),
                rep.makespan_s,
            );
        }
        if let Some(s) = measured {
            point(format!("cli partition measured epoch (c={chunks})"), s);
        }
    }
    ctx.engine.clear_cache();

    // The full search the `partition` subcommand runs, priced on the
    // same profile; its winner is a pure function of these inputs.
    let cons = SweepConstraints::defaults(devices, &chunk_counts);
    let report = sweep(&profile, &cons)?;
    let winner = report.winner();
    table.row(&[
        format!("{}", winner.chunks),
        "sweep".to_string(),
        format!("{:?} ({})", winner.balance, winner.schedule),
        format!("{:.4e} s", winner.epoch_s),
        "-".to_string(),
        format!("{:.4e} s", winner.bottleneck_s),
        format!("{:.3}", winner.bubble_fraction),
    ]);
    let _ = writeln!(
        csv,
        "{},sweep,\"{:?}\",{},{:.6e},,{:.6e},{:.4}",
        winner.chunks,
        winner.balance,
        winner.schedule,
        winner.epoch_s,
        winner.bottleneck_s,
        winner.bubble_fraction,
    );
    point("cli partition sweep winner epoch".to_string(), winner.epoch_s);

    ctx.write_csv("partition.csv", &csv)?;
    write_partition_snapshot(ctx, winner.chunks, &winner.balance, &snapshot)?;
    Ok(format!(
        "Auto-partitioning — {} {ds_name}, schedule {}, {devices} devices, \
         closed-form profile (source {})\n{}\n\
         DP modeled epoch <= hand-authored at every chunk count: {}\n\
         sweep winner: balance {:?} chunks {} schedule {} — replayable from \
         (profile, constraints) alone; `gnn-pipe partition --out` writes it \
         as a partition file\n",
        framework_label(&backend),
        ctx.schedule.name(),
        profile.source,
        table.render(),
        if dp_never_worse { "PASS" } else { "FAIL" },
        winner.balance,
        winner.chunks,
        winner.schedule,
    ))
}

/// Write the `BENCH_partition.json` perf-trajectory snapshot. Same
/// dual-writer convention as `BENCH_faults.json`: this CLI sweep writes
/// `quick: false`, CI's `cargo bench --bench partition -- --quick`
/// writes `quick: true`, and `bench_diff.py` skips mixed pairs.
fn write_partition_snapshot(
    ctx: &BenchCtx,
    winner_chunks: usize,
    winner_balance: &[usize],
    samples: &[BenchSample],
) -> Result<()> {
    let extras = [
        ("quick", "false".to_string()),
        ("source", "\"gnn-pipe bench partition\"".to_string()),
        ("winner_chunks", winner_chunks.to_string()),
        ("winner_balance", format!("\"{winner_balance:?}\"")),
    ];
    let path = ctx.cfg.root.join("BENCH_partition.json");
    write_bench_snapshot(&path, "partition", &extras, samples)?;
    eprintln!("[bench] wrote {}", path.display());
    Ok(())
}
