//! The replica layer above the pipeline engine: hybrid data×pipe
//! parallelism.
//!
//! [`ReplicaGroup`] runs R pipeline instances over one partitioned
//! micro-batch set. The trainer plans `R * chunks` chunks with the
//! existing [`Chunker`] (so the prepared set — and every [`PrepMode`]
//! feed: pooled rebuild, cache, prefetcher — is built once for the
//! whole group); replica `r` trains the contiguous slice of `chunks`
//! micro-batches starting at `r * chunks`, through the *same* compiled
//! stage executables (shapes are per total-chunk-count, so every
//! replica's micro-batches share one padded layout).
//!
//! After the R epochs, per-replica gradient sums are folded by
//! [`tree_allreduce`] — a fixed binary-tree association over replica
//! indices — so the merged gradients, and therefore the whole training
//! trajectory, are **bit-reproducible for any fixed R** regardless of
//! how the replicas were executed.
//!
//! On this host the replicas execute sequentially (one CPU executes
//! all "devices" anyway, exactly as the stage workers of one pipeline
//! already share it); the DGX hybrid projection
//! (`simulator::Scenarios::hybrid_epoch`) prices the truly parallel
//! layout — R nodes × S V100s, NVLink intra-node, the gradient tree on
//! the modeled inter-node link.
//!
//! Dropout keys are assigned by *global* micro-batch index (replica
//! `r`, local batch `m` uses key `base + r*chunks + m`), so an R-way
//! replicated run consumes exactly the per-micro-batch randomness of
//! the equivalent single pipeline over the same `R * chunks` plan —
//! the two differ only in gradient summation association.
//!
//! [`Chunker`]: crate::batching::Chunker
//! [`PrepMode`]: super::PrepMode
//! [`tree_allreduce`]: crate::optim::allreduce::tree_allreduce

use anyhow::Result;

use crate::metrics::Timer;
use crate::optim::allreduce::tree_allreduce;
use crate::runtime::HostTensor;

use super::chunkprep::Microbatch;
use super::engine::{EpochOutput, PipelineEngine, StageTiming};

/// R replicated pipeline instances sharing one engine's compiled
/// stages. `replicas == 1` is byte-for-byte the plain single-pipeline
/// path: no slicing, no reduction, no clone.
pub struct ReplicaGroup<'p> {
    pipe: &'p PipelineEngine,
    pub replicas: usize,
}

impl<'p> ReplicaGroup<'p> {
    pub fn new(pipe: &'p PipelineEngine, replicas: usize) -> Result<ReplicaGroup<'p>> {
        anyhow::ensure!(replicas >= 1, "replicas must be >= 1, got {replicas}");
        Ok(ReplicaGroup { pipe, replicas })
    }

    /// Run one optimiser step's worth of work: every replica's pipeline
    /// epoch over its micro-batch slice, then the deterministic gradient
    /// all-reduce. The returned [`EpochOutput`] has the same shape a
    /// single pipeline over all `microbatches` would produce (grads are
    /// the total sum, `loss_sum`/`mask_count` the totals, `logp` and
    /// per-stage timings concatenated in replica order), so the trainer
    /// loop is replica-agnostic.
    pub fn run_epoch(
        &self,
        params: &[HostTensor],
        microbatches: &[Microbatch],
        key: (u32, u32),
    ) -> Result<EpochOutput> {
        if self.replicas == 1 {
            // The exact pre-replica single-pipeline code path.
            return self.pipe.run_epoch(params, microbatches, key);
        }
        let r = self.replicas;
        anyhow::ensure!(
            microbatches.len() % r == 0 && microbatches.len() >= r,
            "{} micro-batches cannot be split over {r} replicas",
            microbatches.len()
        );
        let per = microbatches.len() / r;

        // Sequential execution in replica-index order; determinism does
        // not depend on it (the reduction order below is fixed), but it
        // keeps one CPU honestly executing one pipeline at a time.
        let mut outs = Vec::with_capacity(r);
        for i in 0..r {
            let slice = &microbatches[i * per..(i + 1) * per];
            // Global micro-batch index keys: replica i, local batch m
            // draws key.0 + i*per + m (the engine adds the local m).
            let rkey = (key.0.wrapping_add((i * per) as u32), key.1);
            outs.push(self.pipe.run_epoch(params, slice, rkey)?);
        }

        // Merge in fixed replica order (f64 scalar sums), then the
        // fixed-association tree reduction over the f32 gradients.
        let n_stages = outs[0].stage_timings.len();
        let mut loss_sum = 0.0f64;
        let mut mask_count = 0.0f64;
        let mut logp: Vec<(Vec<u32>, Vec<f32>)> = Vec::new();
        let mut stage_timings = vec![StageTiming::default(); n_stages];
        let mut wall_s = 0.0f64;
        let mut grad_parts = Vec::with_capacity(r);
        for out in outs {
            loss_sum += out.loss_sum;
            mask_count += out.mask_count;
            logp.extend(out.logp);
            wall_s += out.wall_s;
            for (s, st) in out.stage_timings.into_iter().enumerate() {
                stage_timings[s].fwd_s.extend(st.fwd_s);
                stage_timings[s].bwd_s.extend(st.bwd_s);
                stage_timings[s].busy_s += st.busy_s;
            }
            grad_parts.push(out.grads);
        }
        let reduce = Timer::start();
        let grads = tree_allreduce(grad_parts)?;
        Ok(EpochOutput {
            loss_sum,
            mask_count,
            grads,
            logp,
            stage_timings,
            wall_s,
            allreduce_s: reduce.secs(),
        })
    }
}
