//! Quickstart: generate a synthetic Cora, train the GAT for 30 epochs on
//! the CPU through the compiled HLO artifacts, print accuracy.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;

use gnn_pipe::config::Config;
use gnn_pipe::data::generate;
use gnn_pipe::runtime::Engine;
use gnn_pipe::train::SingleDeviceTrainer;

fn main() -> Result<()> {
    // 1. Load the shared configuration (configs/*.json).
    let cfg = Config::load()?;

    // 2. Bring up the PJRT engine over the AOT artifacts.
    let engine = Engine::from_artifacts_dir(&cfg.artifacts_dir())?;

    // 3. Synthesise the Cora-profile citation graph (seeded, matched to
    //    the published statistics).
    let ds = generate(cfg.dataset("cora")?)?;
    println!(
        "cora: {} nodes, {} edges, {} features, {} classes",
        ds.graph.num_nodes(),
        ds.graph.num_edges(),
        ds.profile.features,
        ds.profile.classes
    );

    // 4. Train the 2-layer, 8-head GAT (paper §2.1) with Adam.
    let trainer = SingleDeviceTrainer::new(&engine, &ds, "ell");
    let res = trainer.train(&cfg.model, 30)?;

    // 5. Report.
    println!(
        "30 epochs in {:.1}s ({:.3}s/epoch after setup)",
        res.timing.total_s(),
        res.timing.avg_epoch_s()
    );
    println!(
        "train acc {:.3}  val acc {:.3}  test acc {:.3}",
        res.final_metrics.train_acc,
        res.final_metrics.val_acc,
        res.final_metrics.test_acc
    );
    println!("loss: {}", res.train_loss.sparkline(50));
    Ok(())
}
