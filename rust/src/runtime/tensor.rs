//! Host-side tensors: the Send-able currency of the coordinator.
//!
//! PJRT `Literal`s wrap raw C pointers and are not `Send`; activations
//! crossing pipeline-stage threads travel as `HostTensor`s instead (one
//! copy per stage boundary — which is also exactly the device-to-device
//! transfer the paper's DGX pays, so the cost model charges it there).

use anyhow::Result;

use super::manifest::{Dtype, TensorMeta};

#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    S32 { shape: Vec<usize>, data: Vec<i32> },
    U32 { shape: Vec<usize>, data: Vec<u32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::F32 { shape, data }
    }

    pub fn s32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::S32 { shape, data }
    }

    pub fn u32(shape: Vec<usize>, data: Vec<u32>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor::U32 { shape, data }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor::F32 { shape, data: vec![0.0; n] }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::F32 { shape: vec![], data: vec![v] }
    }

    /// RNG key tensor: uint32[2], the model's only stochastic input.
    pub fn key(a: u32, b: u32) -> Self {
        HostTensor::U32 { shape: vec![2], data: vec![a, b] }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. }
            | HostTensor::S32 { shape, .. }
            | HostTensor::U32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::S32 { .. } => Dtype::S32,
            HostTensor::U32 { .. } => Dtype::U32,
        }
    }

    pub fn elements(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn byte_len(&self) -> usize {
        4 * self.elements()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            other => anyhow::bail!("expected f32 tensor, got {:?}", other.dtype().name()),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            other => anyhow::bail!("expected f32 tensor, got {:?}", other.dtype().name()),
        }
    }

    pub fn as_s32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::S32 { data, .. } => Ok(data),
            other => anyhow::bail!("expected s32 tensor, got {:?}", other.dtype().name()),
        }
    }

    pub fn as_s32_mut(&mut self) -> Result<&mut Vec<i32>> {
        match self {
            HostTensor::S32 { data, .. } => Ok(data),
            other => anyhow::bail!("expected s32 tensor, got {:?}", other.dtype().name()),
        }
    }

    /// FNV-1a content fingerprint over dtype, shape and raw element bits
    /// — the content identity used by the micro-batch prep cache and the
    /// prep-mode parity tests (bitwise: distinguishes -0.0 from 0.0).
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::hash::Fnv1a::new();
        h.write_u32(match self.dtype() {
            Dtype::F32 => 0,
            Dtype::S32 => 1,
            Dtype::U32 => 2,
        });
        h.write_usize(self.shape().len());
        for &d in self.shape() {
            h.write_usize(d);
        }
        match self {
            HostTensor::F32 { data, .. } => {
                for &v in data {
                    h.write_u32(v.to_bits());
                }
            }
            HostTensor::S32 { data, .. } => {
                for &v in data {
                    h.write_u32(v as u32);
                }
            }
            HostTensor::U32 { data, .. } => {
                for &v in data {
                    h.write_u32(v);
                }
            }
        }
        h.finish()
    }

    pub fn scalar_value(&self) -> Result<f32> {
        let d = self.as_f32()?;
        anyhow::ensure!(d.len() == 1, "not a scalar: shape {:?}", self.shape());
        Ok(d[0])
    }

    /// Validate against a manifest signature entry.
    pub fn check(&self, meta: &TensorMeta) -> Result<()> {
        anyhow::ensure!(
            self.dtype() == meta.dtype,
            "input {:?}: dtype {} != manifest {}",
            meta.name,
            self.dtype().name(),
            meta.dtype.name()
        );
        anyhow::ensure!(
            self.shape() == meta.shape.as_slice(),
            "input {:?}: shape {:?} != manifest {:?}",
            meta.name,
            self.shape(),
            meta.shape
        );
        Ok(())
    }

    // --- Device bridge ----------------------------------------------------

    /// Upload directly to a device buffer (bypasses `Literal` — see the
    /// leak note on `runtime::Executable::client`).
    pub fn to_device_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let buf = match self {
            HostTensor::F32 { shape, data } => {
                client.buffer_from_host_buffer(data, shape, None)?
            }
            HostTensor::S32 { shape, data } => {
                client.buffer_from_host_buffer(data, shape, None)?
            }
            HostTensor::U32 { shape, data } => {
                client.buffer_from_host_buffer(data, shape, None)?
            }
        };
        Ok(buf)
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::S32 { data, .. } => xla::Literal::vec1(data),
            HostTensor::U32 { data, .. } => xla::Literal::vec1(data),
        };
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &xla::Literal, meta: &TensorMeta) -> Result<HostTensor> {
        let t = match meta.dtype {
            Dtype::F32 => HostTensor::F32 {
                shape: meta.shape.clone(),
                data: lit.to_vec::<f32>()?,
            },
            Dtype::S32 => HostTensor::S32 {
                shape: meta.shape.clone(),
                data: lit.to_vec::<i32>()?,
            },
            Dtype::U32 => HostTensor::U32 {
                shape: meta.shape.clone(),
                data: lit.to_vec::<u32>()?,
            },
        };
        anyhow::ensure!(
            t.elements() == lit.element_count(),
            "literal element count {} != manifest shape {:?}",
            lit.element_count(),
            meta.shape
        );
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_dtype_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.elements(), 6);
        assert_eq!(t.byte_len(), 24);
        assert_eq!(t.dtype(), Dtype::F32);
        assert!(t.as_f32().is_ok());
        assert!(t.as_s32().is_err());
    }

    #[test]
    fn check_against_meta() {
        let t = HostTensor::s32(vec![4], vec![1, 2, 3, 4]);
        let good = TensorMeta { name: "labels".into(), shape: vec![4], dtype: Dtype::S32 };
        let bad_shape = TensorMeta { name: "labels".into(), shape: vec![5], dtype: Dtype::S32 };
        let bad_dtype = TensorMeta { name: "labels".into(), shape: vec![4], dtype: Dtype::F32 };
        assert!(t.check(&good).is_ok());
        assert!(t.check(&bad_shape).is_err());
        assert!(t.check(&bad_dtype).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal().unwrap();
        let meta = TensorMeta { name: "x".into(), shape: vec![2, 2], dtype: Dtype::F32 };
        let back = HostTensor::from_literal(&lit, &meta).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn key_tensor() {
        let k = HostTensor::key(7, 9);
        assert_eq!(k.shape(), &[2]);
        assert_eq!(k.dtype(), Dtype::U32);
    }

    #[test]
    fn fingerprint_tracks_content_shape_and_dtype() {
        let a = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let same = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.fingerprint(), same.fingerprint());
        let other_data = HostTensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 5.0]);
        assert_ne!(a.fingerprint(), other_data.fingerprint());
        let other_shape = HostTensor::f32(vec![4], vec![1.0, 2.0, 3.0, 4.0]);
        assert_ne!(a.fingerprint(), other_shape.fingerprint());
        let other_dtype = HostTensor::s32(vec![2, 2], vec![1, 2, 3, 4]);
        assert_ne!(a.fingerprint(), other_dtype.fingerprint());
    }
}
