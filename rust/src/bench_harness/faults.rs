//! E13 — fault injection / failover: measured completion, failover and
//! degradation under seeded chaos plans vs the
//! `Scenarios::fleet_availability` closed-form model, across
//! (scenario, replicas) operating points.
//!
//! Each row replays one deterministic trace through the fleet under
//! one [`FaultPlan`]: a crash reroutes the victim's unserved suffix to
//! the survivors, a stall dooms its replica via the stage-link
//! watchdog (shortened here so the bench doesn't sit out the default
//! 10 s), slow/flaky rows exercise the execution-fault path (injected
//! per-batch delay, bounded transient retries). The completion column
//! (served / offered) is compared against the availability model
//! priced from the row's own chaos plan (`capacity_summary`).
//!
//! Emits `serve_faults.csv` and a `BENCH_faults.json` snapshot (CLI
//! writer: `quick: false`; CI's trajectory job uses
//! `benches/faults.rs` instead — same dual-writer convention as
//! `BENCH_fleet.json`).

use std::fmt::Write as _;

use anyhow::Result;

use crate::faults::{FaultPlan, FaultScenario};
use crate::metrics::{write_bench_snapshot, BenchSample, Table};
use crate::pipeline::PipelineSpec;
use crate::serve::{
    generate_trace, BatchPolicy, FleetPolicy, FleetSession, RouterKind,
    TraceSpec, TrafficShape,
};
use crate::simulator::Scenarios;
use crate::train::{flatten_params, init_params};

use super::{framework_label, BenchCtx};

/// Watchdog for the stall rows: far below the generated 30-60 s stall
/// durations (so the doom fires) but long enough to never trip on real
/// stage work.
const BENCH_STALL_WATCHDOG_S: f64 = 1.0;

/// E13: seeded chaos scenarios against the serving fleet — measured
/// completion/failover/retries vs the closed-form availability model.
pub fn bench_serve_faults(ctx: &BenchCtx) -> Result<String> {
    let sc = &ctx.cfg.serve;
    let backend = sc.backend.clone();
    let ds_name = ctx.cfg.pipeline.pipeline_dataset.clone();
    if !FleetSession::artifacts_available(&ctx.engine, &ds_name, &backend) {
        return Ok(format!(
            "Fault injection — skipped: {ds_name}/{backend} serving artifacts \
             not in the manifest (artifact dir predates the serving \
             subsystem; re-run `make artifacts`)\n"
        ));
    }
    let ds = ctx.dataset(&ds_name)?;
    let profile = ctx.cfg.dataset(&ds_name)?;
    let params_map = init_params(profile, &ctx.cfg.model, sc.seed);
    let params = flatten_params(&params_map, &ctx.engine.manifest.param_order)?;
    let mut session = FleetSession::new(&ctx.engine, ds, &backend);

    let wait_s = sc.max_wait_ms / 1e3;
    let policy = BatchPolicy { max_batch: sc.max_batch, max_wait_s: wait_s };
    let stages = PipelineSpec::gat4_serve().num_stages();
    let requests = sc.requests.max(8).min(32 * sc.max_batch);
    let fault_seed = sc.fault_seed;

    // The sweep: each scenario at a fleet wide enough to survive it,
    // plus the healthy baseline the failover rows are judged against.
    let points: Vec<(FaultScenario, usize)> = vec![
        (FaultScenario::None, 3),
        (FaultScenario::Crash, 3),
        (FaultScenario::Stall, 2),
        (FaultScenario::Slow, 2),
        (FaultScenario::Flaky, 2),
        (FaultScenario::Chaos, 3),
    ];

    let mut table = Table::new(&[
        "Scenario",
        "R",
        "Served/Failover/Degraded",
        "Retries",
        "Failed",
        "Completion",
        "Expected (model)",
        "Thpt req/s",
    ]);
    let mut csv = String::from(
        "scenario,replicas,fault_seed,requests,served,shed,failover,degraded,\
         retries,failed,completion,model_completion,throughput_rps,wall_s\n",
    );
    let mut snapshot: Vec<BenchSample> = Vec::new();

    for &(scenario, replicas) in &points {
        let fleet = FleetPolicy {
            replicas,
            router: RouterKind::Jsq,
            slo: None,
            service_model_s: sc.service_model_ms.max(0.0) / 1e3,
        };
        // Stall rows shorten the watchdog so the doom resolves fast;
        // everything else keeps the serving default.
        let watchdog_s = if scenario == FaultScenario::Stall {
            BENCH_STALL_WATCHDOG_S
        } else {
            crate::serve::DEFAULT_WATCHDOG_S
        };
        session.set_watchdog_s(watchdog_s);
        let plan =
            FaultPlan::generate(scenario, fault_seed, replicas, stages, requests);
        let faults = (scenario != FaultScenario::None).then_some(&plan);
        let trace = generate_trace(
            &TraceSpec { rate_hz: sc.rate_hz, requests, seed: sc.seed },
            TrafficShape::Poisson,
            profile.nodes,
        );
        eprintln!(
            "[bench] serve-faults {ds_name}/{backend} scenario={} R={replicas} \
             fault_seed={fault_seed} requests={requests}...",
            scenario.name()
        );
        let out = session.run_with_faults(&params, &trace, &policy, &fleet, faults)?;
        let r = &out.report;
        let completion = r.served.saturating_sub(r.failed) as f64 / r.offered as f64;
        let (crashed, crash_frac) =
            plan.capacity_summary(replicas, requests, watchdog_s);
        let avail = Scenarios::fleet_availability(
            &r.stage_fwd_means_s,
            r.admitted_rps,
            replicas,
            sc.max_batch,
            wait_s,
            crashed,
            crash_frac,
        );

        table.row(&[
            scenario.name().to_string(),
            format!("{replicas}"),
            format!("{}/{}/{}", r.served, r.failover, r.degraded),
            format!("{}", r.retries),
            format!("{}", r.failed),
            format!("{:.1}%", completion * 100.0),
            format!("{:.1}%", avail.expected_completion * 100.0),
            format!("{:.1}", r.throughput_rps),
        ]);
        let _ = writeln!(
            csv,
            "{},{replicas},{fault_seed},{requests},{},{},{},{},{},{},\
             {:.4},{:.4},{:.3},{:.6}",
            scenario.name(),
            r.served,
            r.shed,
            r.failover,
            r.degraded,
            r.retries,
            r.failed,
            completion,
            avail.expected_completion,
            r.throughput_rps,
            r.wall_s,
        );
        let tag = format!("{},R={replicas}", scenario.name());
        let mut point = |name: String, mean_s: f64| {
            snapshot.push(BenchSample {
                name,
                iters: requests,
                mean_s,
                std_s: 0.0,
                min_s: mean_s,
            });
        };
        point(format!("cli faults total p99 ({tag})"), r.total.p99_s);
        point(
            format!("cli faults per-request service ({tag})"),
            r.wall_s / r.served.max(1) as f64,
        );
        point(format!("cli faults completion ({tag})"), completion);
        point(
            format!("cli faults model completion ({tag})"),
            avail.expected_completion,
        );
    }
    ctx.engine.clear_cache();

    ctx.write_csv("serve_faults.csv", &csv)?;
    write_faults_snapshot(ctx, &snapshot)?;
    Ok(format!(
        "Fault injection / failover — {} {ds_name}, JSQ router, \
         {requests} requests/point, B={} wait {:.0} ms (trace seed {}, \
         fault seed {fault_seed})\n{}\n\
         completion = (served - failed) / offered; the model column is \
         Scenarios::fleet_availability priced from each row's chaos plan \
         (capacity_summary). Stall rows run a {BENCH_STALL_WATCHDOG_S:.0} s \
         watchdog so the doomed replica's StageTimeout resolves quickly; \
         logits of every completed request are bit-identical to the \
         fault-free run (integration_faults pins this)\n",
        framework_label(&backend),
        sc.max_batch,
        sc.max_wait_ms,
        sc.seed,
        table.render()
    ))
}

/// Write the `BENCH_faults.json` perf-trajectory snapshot. Same
/// dual-writer convention as `BENCH_fleet.json`: this CLI sweep writes
/// `quick: false`, CI's `cargo bench --bench faults -- --quick` writes
/// `quick: true`, and `bench_diff.py` skips mixed pairs.
fn write_faults_snapshot(ctx: &BenchCtx, samples: &[BenchSample]) -> Result<()> {
    let extras = [
        ("quick", "false".to_string()),
        ("source", "\"gnn-pipe bench serve-faults\"".to_string()),
    ];
    let path = ctx.cfg.root.join("BENCH_faults.json");
    write_bench_snapshot(&path, "faults", &extras, samples)?;
    eprintln!("[bench] wrote {}", path.display());
    Ok(())
}
