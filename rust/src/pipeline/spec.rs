//! Declarative pipeline description: [`PipelineSpec`] names, per stage,
//! the compiled artifacts to run, the extra micro-batch inputs each one
//! consumes, and the flat-parameter slice the stage owns.
//!
//! The engine builds one generic worker per [`StageSpec`] and the
//! simulator prices the same description, so the real executor and the
//! cost model can never drift apart on pipeline shape. The paper's
//! 4-stage GAT partition ([2,1,2,1] — Listing 1) is one instance,
//! [`PipelineSpec::gat4`]; any staged model the artifact manifest
//! describes can be expressed the same way.

use anyhow::Result;

/// One extra input consumed by a stage executable, appended (in the
/// declared order) after the stage's parameter slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageInput {
    /// The activation received from the upstream stage. Forwards that
    /// declare it receive it over the stage link; backwards that declare
    /// it replay the stashed copy (GPipe rematerialisation stashes only
    /// stage inputs).
    Activation,
    /// The micro-batch node-feature tensor `x`.
    Features,
    /// The micro-batch graph tensors (ELL: idx, mask; COO: src, dst,
    /// mask), in artifact order.
    Graph,
    /// The per-micro-batch dropout key.
    Key,
    /// The micro-batch labels and loss mask (loss-stage backward only).
    LabelsMask,
}

/// One pipeline stage: artifact kinds, input layout, parameter slice.
///
/// Artifact input contract, shared with `python/compile/stages.py`:
///
/// * forward inputs are `params ++ fwd_inputs`, and its first output is
///   the activation handed downstream (on the final stage: the
///   log-probabilities the trainer records);
/// * backward inputs are `params ++ bwd_inputs`, with the downstream
///   cotangent appended last on every stage except the final (loss)
///   stage, whose backward derives its own cotangent from labels+mask;
/// * backward outputs are `[loss_sum, mask_count] ++` (final stage only)
///   `param_grads ++ [upstream_cotangent]` (all but the first stage).
#[derive(Debug, Clone, PartialEq)]
pub struct StageSpec {
    /// Artifact kind of the stage forward (e.g. `"s0_fwd"`); the engine
    /// expands kinds to `{dataset}_{backend}_c{chunks}_{kind}` names.
    pub fwd_kind: String,
    /// Artifact kind of the rematerialising stage backward.
    pub bwd_kind: String,
    /// Half-open slice `[start, end)` of the flat parameter vector this
    /// stage owns (empty slice = stateless stage).
    pub params: (usize, usize),
    /// Ordered extra inputs of the forward executable.
    pub fwd_inputs: Vec<StageInput>,
    /// Ordered extra inputs of the backward executable (cotangent
    /// appended separately; see the struct docs).
    pub bwd_inputs: Vec<StageInput>,
}

impl StageSpec {
    pub fn param_count(&self) -> usize {
        self.params.1 - self.params.0
    }

    /// Stages that consume graph tensors pay the host re-build round
    /// trip when micro-batching is on (the paper's §7.2 overhead); the
    /// simulator charges the stall exactly here.
    pub fn needs_graph(&self) -> bool {
        self.fwd_inputs.contains(&StageInput::Graph)
    }

    fn needs_activation(&self) -> bool {
        self.fwd_inputs.contains(&StageInput::Activation)
    }

    /// The backward replays the stashed stage input (rematerialisation).
    pub fn stashes_activation(&self) -> bool {
        self.bwd_inputs.contains(&StageInput::Activation)
    }
}

/// A full N-stage pipeline: what [`PipelineEngine`] builds workers from
/// and what `simulator::scenarios` prices.
///
/// [`PipelineEngine`]: super::PipelineEngine
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineSpec {
    pub stages: Vec<StageSpec>,
    /// Total flat parameter count; the stage slices must tile exactly
    /// `[0, param_count)` (checked by [`PipelineSpec::validate`]).
    pub param_count: usize,
    /// Inference-only pipeline: stages have no backward executables and
    /// `bwd_kind`/`bwd_inputs` are ignored (conventionally `bwd_kind`
    /// mirrors `fwd_kind` and `bwd_inputs` is empty). Forward-only
    /// specs are executed exclusively through
    /// `PipelineEngine::new_forward_only` + `run_forward` with a
    /// forward-only schedule; the training constructor and `run_epoch`
    /// reject them.
    pub forward_only: bool,
}

impl PipelineSpec {
    /// The paper's 4-stage GAT partition over the [2,1,2,1] balance:
    /// `[Dropout+GAT1] [ELU+Dropout] [GAT2] [LogSoftmax+loss]`, with the
    /// two GAT stages owning 4 parameters each.
    pub fn gat4() -> PipelineSpec {
        use StageInput::{Activation, Features, Graph, Key, LabelsMask};
        PipelineSpec {
            stages: vec![
                StageSpec {
                    fwd_kind: "s0_fwd".into(),
                    bwd_kind: "s0_bwd".into(),
                    params: (0, 4),
                    fwd_inputs: vec![Features, Graph, Key],
                    bwd_inputs: vec![Features, Graph, Key],
                },
                StageSpec {
                    fwd_kind: "s1_fwd".into(),
                    bwd_kind: "s1_bwd".into(),
                    params: (4, 4),
                    fwd_inputs: vec![Activation, Key],
                    bwd_inputs: vec![Activation, Key],
                },
                StageSpec {
                    fwd_kind: "s2_fwd".into(),
                    bwd_kind: "s2_bwd".into(),
                    params: (4, 8),
                    fwd_inputs: vec![Activation, Graph, Key],
                    bwd_inputs: vec![Activation, Graph, Key],
                },
                StageSpec {
                    fwd_kind: "s3_fwd".into(),
                    bwd_kind: "s3loss_bwd".into(),
                    params: (8, 8),
                    fwd_inputs: vec![Activation],
                    bwd_inputs: vec![Activation, LabelsMask],
                },
            ],
            param_count: 8,
            forward_only: false,
        }
    }

    /// The serving counterpart of [`PipelineSpec::gat4`]: the same
    /// [2,1,2,1] stage cut, but deterministic (dropout off, no key
    /// input) and forward-only. Stages 0-2 run the `s{i}_eval_fwd`
    /// artifacts (see `python/compile/stages.py`); stage 3 reuses the
    /// training `s3_fwd` (LogSoftmax is already deterministic). At
    /// chunks = 1 the micro-batch is the intact full graph, so the
    /// staged forward computes exactly the fused `eval_fwd` evaluation
    /// — the serve-vs-`full_eval` logit parity pinned by
    /// `rust/tests/integration_serve.rs`.
    pub fn gat4_serve() -> PipelineSpec {
        use StageInput::{Activation, Features, Graph};
        let fwd_stage = |kind: &str,
                         params: (usize, usize),
                         fwd_inputs: Vec<StageInput>| StageSpec {
            fwd_kind: kind.into(),
            // Placeholder only: forward-only engines never load or run
            // a backward executable.
            bwd_kind: kind.into(),
            params,
            fwd_inputs,
            bwd_inputs: vec![],
        };
        PipelineSpec {
            stages: vec![
                fwd_stage("s0_eval_fwd", (0, 4), vec![Features, Graph]),
                fwd_stage("s1_eval_fwd", (4, 4), vec![Activation]),
                fwd_stage("s2_eval_fwd", (4, 8), vec![Activation, Graph]),
                fwd_stage("s3_fwd", (8, 8), vec![Activation]),
            ],
            param_count: 8,
            forward_only: true,
        }
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Every artifact kind the engine will compile, fwd then bwd per
    /// stage, in stage order. Forward-only specs list only the forward
    /// kinds (their `bwd_kind` is a placeholder, never compiled).
    pub fn artifact_kinds(&self) -> Vec<&str> {
        if self.forward_only {
            return self.stages.iter().map(|s| s.fwd_kind.as_str()).collect();
        }
        self.stages
            .iter()
            .flat_map(|s| [s.fwd_kind.as_str(), s.bwd_kind.as_str()])
            .collect()
    }

    /// Structural checks the generic worker relies on.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.stages.len() >= 2,
            "a pipeline needs at least 2 stages, got {}",
            self.stages.len()
        );
        for (s, st) in self.stages.iter().enumerate() {
            anyhow::ensure!(
                st.params.0 <= st.params.1 && st.params.1 <= self.param_count,
                "stage {s}: param slice {:?} outside [0, {})",
                st.params,
                self.param_count
            );
            anyhow::ensure!(
                (s == 0) != st.needs_activation(),
                "stage {s}: {}",
                if s == 0 {
                    "the first stage cannot consume an upstream activation"
                } else {
                    "every stage after the first must consume the upstream activation"
                }
            );
            if self.forward_only {
                // No backward ever runs: the bwd fields are placeholders
                // and must stay empty so nothing is stashed per batch
                // (a streaming serve run would otherwise accumulate one
                // activation per batch, unbounded).
                anyhow::ensure!(
                    st.bwd_inputs.is_empty(),
                    "stage {s}: forward-only specs must not declare \
                     backward inputs"
                );
                // Serving forwards are deterministic: no dropout keys.
                // (The engine relies on this to skip building the
                // per-batch key tensors on long serve traces.)
                anyhow::ensure!(
                    !st.fwd_inputs.contains(&StageInput::Key),
                    "stage {s}: forward-only specs must be deterministic \
                     (no dropout-key input)"
                );
                continue;
            }
            anyhow::ensure!(
                s > 0 || !st.stashes_activation(),
                "stage 0 has no upstream activation to stash for its backward"
            );
            // The engine treats the final stage as the loss stage: its
            // backward must emit (loss_sum, mask_count, ...) — which
            // requires consuming labels+mask — and no other stage may,
            // or the generic worker would mis-slice its outputs.
            anyhow::ensure!(
                (s == self.stages.len() - 1)
                    == st.bwd_inputs.contains(&StageInput::LabelsMask),
                "stage {s}: {}",
                if s == self.stages.len() - 1 {
                    "the final (loss) stage backward must consume labels+mask"
                } else {
                    "only the final (loss) stage backward may consume labels+mask"
                }
            );
        }
        // The owned parameter slices must tile [0, param_count) exactly
        // so stage-local gradient accumulators concatenate back into the
        // manifest's flat order.
        let mut owned: Vec<(usize, usize)> = self
            .stages
            .iter()
            .map(|s| s.params)
            .filter(|(a, b)| a < b)
            .collect();
        owned.sort_unstable();
        let mut next = 0usize;
        for (a, b) in owned {
            anyhow::ensure!(
                a == next,
                "parameter slices must tile the flat vector: gap or \
                 overlap at index {a} (expected {next})"
            );
            next = b;
        }
        anyhow::ensure!(
            next == self.param_count,
            "parameter slices cover [0, {next}) but param_count is {}",
            self.param_count
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gat4_is_valid() {
        let spec = PipelineSpec::gat4();
        spec.validate().unwrap();
        assert_eq!(spec.num_stages(), 4);
        assert_eq!(
            spec.artifact_kinds(),
            vec![
                "s0_fwd", "s0_bwd", "s1_fwd", "s1_bwd", "s2_fwd", "s2_bwd",
                "s3_fwd", "s3loss_bwd",
            ]
        );
        assert!(spec.stages[0].needs_graph());
        assert!(!spec.stages[1].needs_graph());
        assert!(spec.stages[2].needs_graph());
        assert!(!spec.stages[0].stashes_activation());
        assert!(spec.stages[3].stashes_activation());
    }

    #[test]
    fn gat4_serve_is_valid_and_forward_only() {
        let spec = PipelineSpec::gat4_serve();
        spec.validate().unwrap();
        assert!(spec.forward_only);
        assert_eq!(spec.num_stages(), 4);
        // Forward kinds only: the placeholder bwd kinds never compile.
        assert_eq!(
            spec.artifact_kinds(),
            vec!["s0_eval_fwd", "s1_eval_fwd", "s2_eval_fwd", "s3_fwd"]
        );
        // Same parameter tiling as the training spec (the serve path
        // takes the identical flat parameter vector).
        let train = PipelineSpec::gat4();
        for (a, b) in spec.stages.iter().zip(&train.stages) {
            assert_eq!(a.params, b.params);
        }
        // Nothing may be stashed per batch in a streaming serve run.
        assert!(spec.stages.iter().all(|s| !s.stashes_activation()));
        // No stage consumes a dropout key: the forward is deterministic.
        assert!(spec
            .stages
            .iter()
            .all(|s| !s.fwd_inputs.contains(&StageInput::Key)));
    }

    #[test]
    fn validate_rejects_forward_only_with_bwd_inputs() {
        let mut spec = PipelineSpec::gat4_serve();
        spec.stages[1].bwd_inputs = vec![StageInput::Activation];
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_forward_only_with_dropout_key() {
        let mut spec = PipelineSpec::gat4_serve();
        spec.stages[1].fwd_inputs.push(StageInput::Key);
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_param_gap() {
        let mut spec = PipelineSpec::gat4();
        spec.stages[2].params = (5, 8);
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_param_overlap() {
        let mut spec = PipelineSpec::gat4();
        spec.stages[2].params = (3, 8);
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_uncovered_params() {
        let mut spec = PipelineSpec::gat4();
        spec.param_count = 9;
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_activation_on_first_stage() {
        let mut spec = PipelineSpec::gat4();
        spec.stages[0].fwd_inputs.insert(0, StageInput::Activation);
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_missing_activation_mid_pipeline() {
        let mut spec = PipelineSpec::gat4();
        spec.stages[1].fwd_inputs = vec![StageInput::Key];
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_lossless_final_stage() {
        let mut spec = PipelineSpec::gat4();
        spec.stages[3].bwd_inputs = vec![StageInput::Activation];
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_labels_mask_mid_pipeline() {
        let mut spec = PipelineSpec::gat4();
        spec.stages[1].bwd_inputs.push(StageInput::LabelsMask);
        assert!(spec.validate().is_err());
    }

    #[test]
    fn validate_rejects_single_stage() {
        let mut spec = PipelineSpec::gat4();
        spec.stages.truncate(1);
        spec.param_count = 4;
        assert!(spec.validate().is_err());
    }
}
